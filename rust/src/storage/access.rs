//! The four HDF5 access patterns of §4.4 / Table 3, as request-sequence
//! generators (for the cost model) and as real-file readers (for wall-time
//! measurement in `examples/io_patterns.rs`).
//!
//! Patterns, quoting the paper:
//! 1. **Random access** — a process reads one sample at a random position
//!    until all samples have been accessed once.
//! 2. **Sequential-stride access** — iteratively read samples with a fixed
//!    stride.
//! 3. **Chunk-cycle loading** — load samples one by one within the
//!    process's assigned chunk.
//! 4. **Full-chunk loading** — load the whole assigned chunk in one go.

use anyhow::Result;

use crate::storage::pfs::{CostModel, ReadReq};
use crate::storage::shdf::ShdfReader;
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

/// Which §4.4 access pattern to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccessPattern {
    Random,
    SequentialStride,
    ChunkCycle,
    FullChunk,
}

impl AccessPattern {
    pub fn name(&self) -> &'static str {
        match self {
            AccessPattern::Random => "Random Access",
            AccessPattern::SequentialStride => "Sequential Stride Access",
            AccessPattern::ChunkCycle => "Chunk Cycle Loading",
            AccessPattern::FullChunk => "Full Chunk Loading",
        }
    }

    pub fn all() -> [AccessPattern; 4] {
        [
            AccessPattern::Random,
            AccessPattern::SequentialStride,
            AccessPattern::ChunkCycle,
            AccessPattern::FullChunk,
        ]
    }
}

/// Workload description for one reading process.
#[derive(Debug, Clone)]
pub struct PatternWorkload {
    /// Total samples in the container.
    pub n_samples: usize,
    /// Bytes per sample.
    pub sample_bytes: usize,
    /// Data-region start offset within the file.
    pub data_start: u64,
    /// Number of parallel reader processes (each gets 1/nth of the work).
    pub n_procs: usize,
    /// This process's rank.
    pub rank: usize,
    /// Stride for SequentialStride (in samples); the paper uses the number
    /// of processes as the stride (round-robin assignment).
    pub stride: usize,
}

impl PatternWorkload {
    /// The sample indices this rank reads, in access order.
    pub fn indices(&self, pattern: AccessPattern, rng: &mut Rng) -> Vec<usize> {
        let per = self.n_samples / self.n_procs;
        match pattern {
            AccessPattern::Random => {
                // Round-robin ownership, visited in random order.
                let mut own: Vec<usize> =
                    (0..self.n_samples).filter(|i| i % self.n_procs == self.rank).collect();
                rng.shuffle(&mut own);
                own
            }
            AccessPattern::SequentialStride => {
                // Round-robin ownership visited in increasing order: the
                // process touches every `stride`-th sample.
                (0..self.n_samples).filter(|i| i % self.stride == self.rank % self.stride).collect()
            }
            AccessPattern::ChunkCycle | AccessPattern::FullChunk => {
                // Contiguous chunk ownership.
                let start = self.rank * per;
                let end = if self.rank == self.n_procs - 1 { self.n_samples } else { start + per };
                (start..end).collect()
            }
        }
    }

    /// The PFS request sequence for this rank under `pattern`.
    pub fn requests(&self, pattern: AccessPattern, rng: &mut Rng) -> Vec<ReadReq> {
        let idx = self.indices(pattern, rng);
        let sb = self.sample_bytes as u64;
        match pattern {
            AccessPattern::FullChunk => {
                if idx.is_empty() {
                    return vec![];
                }
                // One request covering the whole assigned chunk.
                let first = *idx.first().unwrap() as u64;
                vec![ReadReq { offset: self.data_start + first * sb, len: idx.len() as u64 * sb }]
            }
            _ => idx
                .iter()
                .map(|&i| ReadReq { offset: self.data_start + i as u64 * sb, len: sb })
                .collect(),
        }
    }

    /// Modeled I/O time for this rank.
    pub fn modeled_time(&self, pattern: AccessPattern, model: &CostModel, rng: &mut Rng) -> f64 {
        model.pfs_sequence(&self.requests(pattern, rng))
    }
}

/// Modeled I/O time for `n_procs` parallel readers = max over ranks
/// (the paper reports the slowest process; all must finish).
pub fn modeled_parallel_time(
    n_samples: usize,
    sample_bytes: usize,
    n_procs: usize,
    pattern: AccessPattern,
    model: &CostModel,
    seed: u64,
) -> f64 {
    let mut worst: f64 = 0.0;
    for rank in 0..n_procs {
        let w = PatternWorkload {
            n_samples,
            sample_bytes,
            data_start: 4108, // SHDF header size; exact value irrelevant to the model
            n_procs,
            rank,
            stride: n_procs,
        };
        let mut rng = Rng::new(seed).fork(rank as u64);
        worst = worst.max(w.modeled_time(pattern, model, &mut rng));
    }
    worst
}

/// Execute a pattern against a real SHDF file and return (wall seconds,
/// bytes read, checksum). The checksum forces the reads to really happen.
pub fn measured_time(
    reader: &mut ShdfReader,
    pattern: AccessPattern,
    n_procs: usize,
    rank: usize,
    seed: u64,
) -> Result<(f64, u64, u64)> {
    let w = PatternWorkload {
        n_samples: reader.n_samples(),
        sample_bytes: reader.sample_bytes(),
        data_start: 0,
        n_procs,
        rank,
        stride: n_procs,
    };
    let mut rng = Rng::new(seed).fork(rank as u64);
    let idx = w.indices(pattern, &mut rng);
    let t = Stopwatch::start();
    let mut bytes = 0u64;
    let mut checksum = 0u64;
    match pattern {
        AccessPattern::FullChunk => {
            if let (Some(&first), len) = (idx.first(), idx.len()) {
                let buf = reader.read_range(first, len)?;
                bytes += buf.len() as u64;
                checksum = checksum.wrapping_add(buf.iter().map(|&b| b as u64).sum::<u64>());
            }
        }
        _ => {
            let mut buf = vec![0u8; reader.sample_bytes()];
            for &i in &idx {
                reader.read_sample_into(i, &mut buf)?;
                bytes += buf.len() as u64;
                checksum = checksum.wrapping_add(buf[0] as u64).wrapping_add(buf[buf.len() - 1] as u64);
            }
        }
    }
    Ok((t.elapsed_s(), bytes, checksum))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn workload(rank: usize) -> PatternWorkload {
        PatternWorkload { n_samples: 64, sample_bytes: 100, data_start: 0, n_procs: 4, rank, stride: 4 }
    }

    #[test]
    fn every_pattern_covers_all_samples_across_ranks() {
        for pattern in AccessPattern::all() {
            let mut seen = vec![false; 64];
            for rank in 0..4 {
                let mut rng = Rng::new(9).fork(rank as u64);
                for i in workload(rank).indices(pattern, &mut rng) {
                    assert!(!seen[i], "{:?}: duplicate {i}", pattern);
                    seen[i] = true;
                }
            }
            assert!(seen.iter().all(|&s| s), "{:?}: missing samples", pattern);
        }
    }

    #[test]
    fn full_chunk_is_one_request() {
        let mut rng = Rng::new(1);
        let reqs = workload(1).requests(AccessPattern::FullChunk, &mut rng);
        assert_eq!(reqs.len(), 1);
        assert_eq!(reqs[0].offset, 16 * 100);
        assert_eq!(reqs[0].len, 16 * 100);
    }

    #[test]
    fn chunk_cycle_requests_are_contiguous() {
        let mut rng = Rng::new(1);
        let reqs = workload(2).requests(AccessPattern::ChunkCycle, &mut rng);
        assert_eq!(reqs.len(), 16);
        for k in 1..reqs.len() {
            assert_eq!(reqs[k].offset, reqs[k - 1].offset + reqs[k - 1].len);
        }
    }

    #[test]
    fn modeled_ordering_matches_paper_table3() {
        // random > seq-stride > chunk-cycle > full-chunk
        let m = CostModel::default();
        let t = |p| modeled_parallel_time(4096, 65536, 4, p, &m, 7);
        let rand = t(AccessPattern::Random);
        let stride = t(AccessPattern::SequentialStride);
        let cycle = t(AccessPattern::ChunkCycle);
        let full = t(AccessPattern::FullChunk);
        assert!(rand > stride, "rand={rand} stride={stride}");
        assert!(stride > cycle, "stride={stride} cycle={cycle}");
        assert!(cycle > full, "cycle={cycle} full={full}");
        // Headline gap should be in the paper's ballpark (203×); accept a
        // generous band since sample count differs from the paper's run.
        let gap = rand / full;
        assert!(gap > 60.0 && gap < 800.0, "random/full gap {gap}");
    }

    #[test]
    fn measured_patterns_read_identical_byte_totals() {
        use crate::storage::shdf::{ShdfHeader, ShdfWriter};
        let dir = std::env::temp_dir().join("solar_access_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("patterns.shdf");
        let mut w = ShdfWriter::create(
            &path,
            ShdfHeader { n_samples: 0, sample_bytes: 64, shape: vec![16], dtype: "f32".into(), name: "t".into() },
        )
        .unwrap();
        for i in 0..32 {
            w.append_f32(&vec![i as f32; 16]).unwrap();
        }
        w.finish().unwrap();
        let mut totals = vec![];
        for pattern in AccessPattern::all() {
            let mut bytes = 0;
            for rank in 0..2 {
                let mut r = ShdfReader::open(&path).unwrap();
                let (_, b, _) = measured_time(&mut r, pattern, 2, rank, 3).unwrap();
                bytes += b;
            }
            totals.push(bytes);
        }
        assert!(totals.iter().all(|&b| b == 32 * 64), "{totals:?}");
    }
}
