//! The committed lint baseline: triaged pre-existing findings that
//! `solar lint --deny` tolerates. Identity is `(rule, file, snippet)` —
//! line numbers drift as files are edited, trimmed source text does not
//! (and when it does, the finding deserves a fresh look anyway).
//!
//! Every entry carries a mandatory `reason`; an entry that no longer
//! matches any finding is *stale* and fails `--deny` too, so the
//! baseline can only shrink in step with the tree.

use anyhow::{bail, Context, Result};
use std::path::Path;

use crate::analysis::rules::Finding;
use crate::util::json::Json;

pub const BASELINE_VERSION: u64 = 1;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BaselineEntry {
    pub rule: String,
    pub file: String,
    pub snippet: String,
    pub reason: String,
}

impl BaselineEntry {
    pub fn matches(&self, f: &Finding) -> bool {
        self.rule == f.rule && self.file == f.file && self.snippet.trim() == f.snippet.trim()
    }
}

#[derive(Debug, Clone, Default)]
pub struct Baseline {
    pub entries: Vec<BaselineEntry>,
}

impl Baseline {
    pub fn empty() -> Baseline {
        Baseline::default()
    }

    pub fn parse(text: &str) -> Result<Baseline> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("bad baseline JSON: {e}"))?;
        let version = j.req_u64("version").map_err(|e| anyhow::anyhow!("{e}"))?;
        if version != BASELINE_VERSION {
            bail!("unsupported baseline version {version} (expected {BASELINE_VERSION})");
        }
        let mut entries = Vec::new();
        for (i, e) in j.req_arr("entries").map_err(|e| anyhow::anyhow!("{e}"))?.iter().enumerate()
        {
            let req = |k: &str| -> Result<String> {
                Ok(e.req_str(k)
                    .map_err(|err| anyhow::anyhow!("baseline entry {i}: {err}"))?
                    .to_string())
            };
            let entry = BaselineEntry {
                rule: req("rule")?,
                file: req("file")?,
                snippet: req("snippet")?,
                reason: req("reason")?,
            };
            if entry.reason.trim().is_empty() {
                bail!("baseline entry {i} ({} {}): empty reason — a justification is mandatory",
                    entry.rule, entry.file);
            }
            entries.push(entry);
        }
        Ok(Baseline { entries })
    }

    pub fn load(path: &Path) -> Result<Baseline> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading baseline {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing baseline {}", path.display()))
    }

    pub fn to_json_string(&self) -> String {
        let entries: Vec<Json> = self
            .entries
            .iter()
            .map(|e| {
                Json::from_pairs(vec![
                    ("rule", Json::Str(e.rule.clone())),
                    ("file", Json::Str(e.file.clone())),
                    ("snippet", Json::Str(e.snippet.clone())),
                    ("reason", Json::Str(e.reason.clone())),
                ])
            })
            .collect();
        let mut root = Json::obj();
        root.set("version", Json::Num(BASELINE_VERSION as f64));
        root.set("entries", Json::Arr(entries));
        let mut s = root.to_string_pretty();
        s.push('\n');
        s
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json_string())
            .with_context(|| format!("writing baseline {}", path.display()))
    }

    pub fn contains(&self, f: &Finding) -> bool {
        self.entries.iter().any(|e| e.matches(f))
    }

    /// Entries matching no current finding — they must be deleted.
    pub fn stale_entries(&self, findings: &[Finding]) -> Vec<&BaselineEntry> {
        self.entries.iter().filter(|e| !findings.iter().any(|f| e.matches(f))).collect()
    }

    /// Capture current findings as a baseline (each entry still needs a
    /// human-written reason before it deserves to be committed).
    pub fn from_findings(findings: &[Finding], reason: &str) -> Baseline {
        let mut entries: Vec<BaselineEntry> = findings
            .iter()
            .map(|f| BaselineEntry {
                rule: f.rule.clone(),
                file: f.file.clone(),
                snippet: f.snippet.clone(),
                reason: reason.to_string(),
            })
            .collect();
        entries.dedup();
        Baseline { entries }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finding(rule: &str, file: &str, line: usize, snippet: &str) -> Finding {
        Finding {
            rule: rule.into(),
            file: file.into(),
            line,
            snippet: snippet.into(),
            message: String::new(),
            hint: String::new(),
        }
    }

    #[test]
    fn roundtrip_and_line_drift_tolerance() {
        let f = finding("R3", "exp/x.rs", 10, "let t = Instant::now();");
        let b = Baseline::from_findings(&[f.clone()], "legacy timer");
        let b2 = Baseline::parse(&b.to_json_string()).unwrap();
        assert_eq!(b2.entries, b.entries);
        // Same code on a different line still matches (identity is
        // rule+file+snippet, not line).
        let drifted = finding("R3", "exp/x.rs", 99, "  let t = Instant::now();  ");
        assert!(b2.contains(&drifted));
        // A different file does not.
        assert!(!b2.contains(&finding("R3", "exp/y.rs", 10, "let t = Instant::now();")));
    }

    #[test]
    fn stale_entries_are_reported() {
        let f = finding("R5", "loader/x.rs", 3, "use ShdfReader;");
        let b = Baseline::from_findings(&[f.clone()], "pre-trait legacy");
        assert!(b.stale_entries(&[f.clone()]).is_empty());
        assert_eq!(b.stale_entries(&[]).len(), 1);
    }

    #[test]
    fn reasons_are_mandatory() {
        let text = r#"{"version": 1, "entries": [{"rule": "R1", "file": "a.rs", "snippet": "x", "reason": "  "}]}"#;
        assert!(Baseline::parse(text).is_err());
        let bad_version = r#"{"version": 2, "entries": []}"#;
        assert!(Baseline::parse(bad_version).is_err());
    }
}
