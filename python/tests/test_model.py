"""L2 correctness: model shapes, masking semantics, pallas-vs-xla parity,
and that a few SGD steps actually reduce the loss."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


def batch(b, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.uniform(k1, (b, 1, model.IMG, model.IMG))
    y = jax.random.uniform(k2, (b, 2, model.IMG, model.IMG))
    return x, y


def test_param_spec_consistent_with_init():
    params = model.init_params(0)
    spec = model.param_spec()
    assert set(params) == {n for n, _ in spec}
    for n, s in spec:
        assert params[n].shape == s, n
    assert model.n_params() == sum(int(np.prod(s)) for _, s in spec)
    # Same order of magnitude as PtychoNN's 1.2M parameters.
    assert 1e6 < model.n_params() < 5e6


def test_forward_shapes():
    params = model.init_params(0)
    x, _ = batch(4)
    out = model.forward(params, x)
    assert out.shape == (4, 2, model.IMG, model.IMG)


def test_pallas_and_xla_paths_agree():
    params = model.init_params(1)
    x, y = batch(8, seed=1)
    mask = jnp.ones((8,))
    out_p = model.forward(params, x, use_pallas=True)
    out_x = model.forward(params, x, use_pallas=False)
    np.testing.assert_allclose(out_p, out_x, rtol=1e-4, atol=1e-5)
    lp, gp = model.grads_fn(params, x, y, mask, use_pallas=True)
    lx, gx = model.grads_fn(params, x, y, mask, use_pallas=False)
    np.testing.assert_allclose(lp, lx, rtol=1e-5, atol=1e-6)
    for n in gp:
        np.testing.assert_allclose(gp[n], gx[n], rtol=2e-3, atol=1e-5, err_msg=n)


def test_mask_zeroes_contribution():
    params = model.init_params(2)
    x, y = batch(4, seed=2)
    full = model.loss_sum(params, x, y, jnp.array([1.0, 1.0, 0.0, 0.0]))
    half = model.loss_sum(params, x[:2], y[:2], jnp.ones((2,)))
    np.testing.assert_allclose(full, half, rtol=1e-6)


def test_grads_sum_additive_across_splits():
    # The coordinator's allreduce correctness: grads of the union batch ==
    # sum of grads of disjoint sub-batches (mask-padded).
    params = model.init_params(3)
    x, y = batch(8, seed=3)
    ones = jnp.ones((8,))
    _, g_all = model.grads_fn(params, x, y, ones, use_pallas=False)
    _, g_a = model.grads_fn(params, x[:4], y[:4], jnp.ones((4,)), use_pallas=False)
    _, g_b = model.grads_fn(params, x[4:], y[4:], jnp.ones((4,)), use_pallas=False)
    for n in g_all:
        np.testing.assert_allclose(g_a[n] + g_b[n], g_all[n], rtol=1e-3, atol=1e-5, err_msg=n)


def test_sgd_reduces_loss():
    params = model.init_params(4)
    x, y = batch(8, seed=4)
    mask = jnp.ones((8,))
    l0, _ = model.grads_fn(params, x, y, mask, use_pallas=False)
    lr = 0.05
    for _ in range(5):
        _, g = model.grads_fn(params, x, y, mask, use_pallas=False)
        params = {n: params[n] - lr * g[n] / 8.0 for n in params}
    l1, _ = model.grads_fn(params, x, y, mask, use_pallas=False)
    assert float(l1) < float(l0), (float(l0), float(l1))


def test_flat_signatures_roundtrip():
    b = 4
    fn, shapes = model.make_grads_flat(b, use_pallas=False)
    assert len(shapes) == len(model.param_spec()) + 3
    params = model.init_params(5)
    x, y = batch(b, seed=5)
    args = [params[n] for n, _ in model.param_spec()] + [x, y, jnp.ones((b,))]
    out = fn(*args)
    assert len(out) == 1 + len(model.param_spec())
    l_direct, g_direct = model.grads_fn(params, x, y, jnp.ones((b,)), use_pallas=False)
    np.testing.assert_allclose(out[0], l_direct, rtol=1e-6)
    np.testing.assert_allclose(out[1], g_direct[model.param_spec()[0][0]], rtol=1e-5, atol=1e-7)
