"""L1 correctness: the Pallas matmul kernel vs the pure-jnp oracle.

hypothesis sweeps shapes and dtypes; explicit tests cover the custom VJP
(the training step differentiates through the kernel) and block selection.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul as pk
from compile.kernels import ref as kref


def rand(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32).astype(dtype)


# Shapes as multiples of small tile edges to exercise several grid layouts.
dims = st.sampled_from([1, 2, 3, 4, 6, 8, 16])
scales = st.sampled_from([1, 2, 4])


@settings(max_examples=25, deadline=None)
@given(m=dims, n=dims, k=dims, s=scales)
def test_matmul_matches_ref_f32(m, n, k, s):
    x = rand(m * 31 + n, (m * s, k * 8 * s), jnp.float32)
    w = rand(k * 17 + 1, (k * 8 * s, n * s), jnp.float32)
    got = pk.matmul(x, w)
    want = kref.matmul_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(m=dims, n=dims, k=dims)
def test_matmul_matches_ref_bf16(m, n, k):
    x = rand(m, (m * 8, k * 16), jnp.bfloat16)
    w = rand(n, (k * 16, n * 8), jnp.bfloat16)
    got = pk.matmul(x, w).astype(jnp.float32)
    want = kref.matmul_ref(x, w).astype(jnp.float32)
    # bf16 inputs, f32 accumulation in both paths.
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def test_explicit_mxu_shape():
    x = rand(0, (128, 512), jnp.float32)
    w = rand(1, (512, 256), jnp.float32)
    np.testing.assert_allclose(pk.matmul(x, w), kref.matmul_ref(x, w), rtol=2e-3, atol=1e-3)


def test_grad_matches_ref():
    x = rand(2, (16, 64), jnp.float32)
    w = rand(3, (64, 32), jnp.float32)

    def f_pallas(x, w):
        return jnp.sum(jnp.sin(pk.matmul(x, w)))

    def f_ref(x, w):
        return jnp.sum(jnp.sin(kref.matmul_ref(x, w)))

    gx_p, gw_p = jax.grad(f_pallas, argnums=(0, 1))(x, w)
    gx_r, gw_r = jax.grad(f_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(gx_p, gx_r, rtol=2e-3, atol=1e-4)
    np.testing.assert_allclose(gw_p, gw_r, rtol=2e-3, atol=1e-4)


def test_dense_bias_relu():
    x = rand(4, (8, 32), jnp.float32)
    w = rand(5, (32, 16), jnp.float32)
    b = rand(6, (16,), jnp.float32)
    got = pk.dense(x, w, b, activation="relu")
    want = kref.dense_ref(x, w, b, activation="relu")
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=1e-3)
    assert (np.asarray(got) >= 0).all()


def test_dense_rejects_unknown_activation():
    x = rand(4, (8, 8), jnp.float32)
    with pytest.raises(ValueError):
        pk.dense(x, x, jnp.zeros((8,)), activation="gelu!!")


def test_pick_block_divides():
    for dim in [1, 2, 7, 8, 24, 96, 128, 4096, 520]:
        b = pk.pick_block(dim)
        assert dim % b == 0
        assert b <= 256


def test_vmem_estimate_under_budget():
    # The model's dense layers must fit VMEM comfortably (DESIGN §Perf).
    for (m, n, k) in [(32, 256, 4096), (32, 4096, 256)]:
        d = pk.describe_blocks(m, n, k)
        assert d["vmem_bytes"] < 16 * 1024 * 1024 / 4, d


def test_kernel_inside_jit():
    x = rand(7, (32, 128), jnp.float32)
    w = rand(8, (128, 64), jnp.float32)
    got = jax.jit(pk.matmul)(x, w)
    np.testing.assert_allclose(got, kref.matmul_ref(x, w), rtol=2e-3, atol=1e-3)
