//! Table 3 on REAL disk I/O: generate an SHDF container, read it under the
//! four §4.4 access patterns, and report measured wall time next to the
//! calibrated cost model's prediction.
//!
//! ```bash
//! cargo run --release --example io_patterns [-- --samples 4096]
//! ```
//!
//! Note: on a local SSD with a warm page cache the wall-time gaps are far
//! smaller than on Lustre — that is exactly why the cost model exists (see
//! DESIGN.md substitutions). The *ordering* still reproduces.

use solar::data::spec::DatasetSpec;
use solar::data::synth;
use solar::storage::access::{measured_time, modeled_parallel_time, AccessPattern};
use solar::storage::pfs::CostModel;
use solar::storage::shdf::ShdfReader;
use solar::util::stats::TextTable;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n_samples = args
        .iter()
        .position(|a| a == "--samples")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(4096usize);

    let mut spec = DatasetSpec::paper("cd17").unwrap();
    spec.n_samples = n_samples;
    spec.id = format!("cd_patterns_{n_samples}");
    let dir = std::env::temp_dir().join("solar_io_patterns");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("patterns.shdf");
    let regenerate = ShdfReader::open(&path).map(|r| r.n_samples() != n_samples).unwrap_or(true);
    if regenerate {
        println!("generating {n_samples} samples ({} MB)...", n_samples * 64 / 1024);
        synth::generate_dataset(&path, &spec, 7)?;
    }

    let n_procs = 4;
    let model = CostModel::default();
    let mut t = TextTable::new(&["Pattern", "measured (s)", "modeled (s)", "modeled speedup"]);
    let modeled_rand =
        modeled_parallel_time(n_samples, spec.sample_bytes, n_procs, AccessPattern::Random, &model, 7);
    for pattern in AccessPattern::all() {
        // Sequential emulation of the parallel processes: total = max over
        // ranks, matching `modeled_parallel_time`.
        let mut worst = 0.0f64;
        let mut bytes = 0u64;
        for rank in 0..n_procs {
            let mut r = ShdfReader::open(&path)?;
            let (secs, b, _) = measured_time(&mut r, pattern, n_procs, rank, 7)?;
            worst = worst.max(secs);
            bytes += b;
        }
        assert_eq!(bytes as usize, n_samples * spec.sample_bytes, "all samples read once");
        let modeled = modeled_parallel_time(n_samples, spec.sample_bytes, n_procs, pattern, &model, 7);
        t.rowv(vec![
            pattern.name().into(),
            format!("{worst:.4}"),
            format!("{modeled:.3}"),
            format!("{:.1}x", modeled_rand / modeled),
        ]);
    }
    println!(
        "Table 3 workload on a real SHDF file ({n_samples} x 64 KiB, {n_procs} readers)\n\
         Paper (Lustre): random 645.9s, stride 84.4s, chunk-cycle 30.5s, full-chunk 3.2s (203x)\n\n{}",
        t.render()
    );
    Ok(())
}
