//! Sharded dataset backend: a directory of SHDF shard files plus a
//! `manifest.json`.
//!
//! Scientific datasets rarely arrive as one giant file — ensemble runs
//! produce one file per simulation (arXiv:2309.16743), and HPC ingest
//! pipelines shard for parallel writes. This backend keeps SOLAR's global
//! sample-id space (shard k holds a consecutive id range; prefix sums map
//! global id → (shard, local id)) while being honest about layout: the
//! [`Contiguity`] it reports has one region per shard, so the chunk
//! aggregator never plans a "single request" spanning two files.
//!
//! Manifest format (`manifest.json`, keys sorted):
//!
//! ```json
//! {
//!   "dtype": "f32",
//!   "format": "shdf-shards-v1",
//!   "n_samples": 1000,
//!   "name": "cd17_s1000",
//!   "sample_bytes": 65536,
//!   "shape": [4, 64, 64],
//!   "shards": [
//!     {"file": "shard_00000.shdf", "n_samples": 250},
//!     {"file": "shard_00001.shdf", "n_samples": 250}
//!   ]
//! }
//! ```
//!
//! Every shard is a self-describing SHDF container; `open` cross-checks
//! each shard header against the manifest so a swapped or truncated shard
//! fails loudly instead of serving wrong bytes.

use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::storage::codec::Codec;
use crate::storage::shdf::{ShdfHeader, ShdfReader, ShdfWriter};
use crate::storage::store::{Contiguity, SampleStore, VarExtents};
use crate::util::json::Json;

pub const FORMAT: &str = "shdf-shards-v1";
pub const MANIFEST: &str = "manifest.json";

/// Parsed sharded-dataset manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardManifest {
    pub name: String,
    pub sample_bytes: usize,
    pub shape: Vec<usize>,
    pub dtype: String,
    /// Total samples across shards.
    pub n_samples: usize,
    /// `(file name, sample count)` per shard, in global-id order.
    pub shards: Vec<(String, usize)>,
    /// Per-sample codec shared by every shard. Serialized only when not
    /// raw (the optional `codec` manifest key), so pre-codec manifests
    /// stay byte-identical and keep parsing.
    pub codec: Codec,
}

impl ShardManifest {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("format", Json::Str(FORMAT.into()))
            .set("name", Json::Str(self.name.clone()))
            .set("sample_bytes", Json::Num(self.sample_bytes as f64))
            .set("shape", Json::arr_usize(&self.shape))
            .set("dtype", Json::Str(self.dtype.clone()))
            .set("n_samples", Json::Num(self.n_samples as f64))
            .set(
                "shards",
                Json::Arr(
                    self.shards
                        .iter()
                        .map(|(file, n)| {
                            let mut s = Json::obj();
                            s.set("file", Json::Str(file.clone()))
                                .set("n_samples", Json::Num(*n as f64));
                            s
                        })
                        .collect(),
                ),
            );
        if !self.codec.is_raw() {
            o.set("codec", Json::Str(self.codec.name().to_string()));
        }
        o
    }

    pub fn from_json(j: &Json) -> Result<ShardManifest> {
        let format = j.req_str("format")?;
        if format != FORMAT {
            bail!("unsupported sharded-dataset format '{format}' (expected '{FORMAT}')");
        }
        let mut shards = Vec::new();
        for s in j.req_arr("shards")? {
            shards.push((s.req_str("file")?.to_string(), s.req_usize("n_samples")?));
        }
        // Absent on every pre-codec manifest; an unknown name is a hard
        // error (reading encoded extents as raw would corrupt samples).
        let codec = match j.get("codec") {
            None => Codec::Raw,
            Some(_) => {
                let name = j.req_str("codec")?;
                Codec::by_name(name).with_context(|| format!("unsupported codec '{name}'"))?
            }
        };
        let m = ShardManifest {
            name: j.req_str("name")?.to_string(),
            sample_bytes: j.req_usize("sample_bytes")?,
            shape: j.get("shape").and_then(Json::arr_as_usize).context("manifest missing 'shape'")?,
            dtype: j.req_str("dtype")?.to_string(),
            n_samples: j.req_usize("n_samples")?,
            shards,
            codec,
        };
        let total: usize = m.shards.iter().map(|(_, n)| n).sum();
        if total != m.n_samples {
            bail!("manifest n_samples {} != sum of shard counts {}", m.n_samples, total);
        }
        Ok(m)
    }

    pub fn save(&self, dir: &Path) -> Result<()> {
        let path = dir.join(MANIFEST);
        let tmp = dir.join(format!("{MANIFEST}.tmp"));
        std::fs::write(&tmp, self.to_json().to_string_pretty())
            .with_context(|| format!("write {}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("rename {} -> {}", tmp.display(), path.display()))?;
        Ok(())
    }

    pub fn load(dir: &Path) -> Result<ShardManifest> {
        let path = dir.join(MANIFEST);
        let text =
            std::fs::read_to_string(&path).with_context(|| format!("read {}", path.display()))?;
        ShardManifest::from_json(&Json::parse(&text).context("manifest json")?)
    }
}

/// Streaming writer for a sharded dataset: appends samples, rolling to a
/// new shard file when the current shard reaches its capacity; `finish`
/// closes the last shard and writes the manifest.
pub struct ShardedWriter {
    dir: PathBuf,
    header: ShdfHeader,
    /// Per-shard capacities; the last entry repeats for any further
    /// shards (a single entry = the fixed-capacity rolling mode).
    caps: Vec<usize>,
    codec: Codec,
    cur: Option<ShdfWriter>,
    cur_count: usize,
    shards: Vec<(String, usize)>,
    total: usize,
}

impl ShardedWriter {
    /// Fixed-capacity mode: roll to a new shard every `shard_capacity`
    /// samples (the shard count follows from how many samples arrive).
    pub fn create(dir: &Path, header: ShdfHeader, shard_capacity: usize) -> Result<ShardedWriter> {
        Self::create_with_codec(dir, header, shard_capacity, Codec::Raw)
    }

    /// Fixed-capacity mode with every shard `codec`-encoded.
    pub fn create_with_codec(
        dir: &Path,
        header: ShdfHeader,
        shard_capacity: usize,
        codec: Codec,
    ) -> Result<ShardedWriter> {
        if shard_capacity == 0 {
            bail!("shard_capacity must be > 0");
        }
        Self::with_caps(dir, header, vec![shard_capacity], codec)
    }

    /// Balanced mode for a known total: exactly `n_shards` shards (capped
    /// at one sample per shard) whose sizes differ by at most one —
    /// `total = 6, n_shards = 4` gives 2+2+1+1, never a collapsed tail.
    pub fn create_balanced(
        dir: &Path,
        header: ShdfHeader,
        total: usize,
        n_shards: usize,
    ) -> Result<ShardedWriter> {
        Self::create_balanced_with_codec(dir, header, total, n_shards, Codec::Raw)
    }

    /// Balanced mode with every shard `codec`-encoded.
    pub fn create_balanced_with_codec(
        dir: &Path,
        header: ShdfHeader,
        total: usize,
        n_shards: usize,
        codec: Codec,
    ) -> Result<ShardedWriter> {
        Self::with_caps(dir, header, Self::balanced_sizes(total, n_shards), codec)
    }

    /// The balanced per-shard sample counts [`create_balanced`] commits
    /// to up front. Fixing the split before any byte is written is what
    /// makes the shards independent: `gen-data` writes them concurrently
    /// from pool workers with byte-identical output to the serial rolling
    /// writer (`synth::generate_dataset_sharded`).
    pub fn balanced_sizes(total: usize, n_shards: usize) -> Vec<usize> {
        let n_shards = n_shards.clamp(1, total.max(1));
        let q = total / n_shards;
        let r = total % n_shards;
        (0..n_shards).map(|k| if k < r { q + 1 } else { q.max(1) }).collect()
    }

    fn with_caps(
        dir: &Path,
        header: ShdfHeader,
        caps: Vec<usize>,
        codec: Codec,
    ) -> Result<ShardedWriter> {
        header.validate()?;
        std::fs::create_dir_all(dir).with_context(|| format!("create {}", dir.display()))?;
        Ok(ShardedWriter {
            dir: dir.to_path_buf(),
            header,
            caps,
            codec,
            cur: None,
            cur_count: 0,
            shards: Vec::new(),
            total: 0,
        })
    }

    /// Canonical shard file name for shard `idx` — shared with the
    /// parallel writer so both layouts name files identically.
    pub fn shard_file(idx: usize) -> String {
        format!("shard_{idx:05}.shdf")
    }

    /// Capacity of the shard currently being written (index =
    /// `shards.len()`); past the planned list, the last capacity repeats.
    fn cur_capacity(&self) -> usize {
        let idx = self.shards.len().min(self.caps.len() - 1);
        self.caps[idx]
    }

    fn roll(&mut self) -> Result<()> {
        if let Some(w) = self.cur.take() {
            let h = w.finish()?;
            self.shards.push((Self::shard_file(self.shards.len()), h.n_samples));
        }
        self.cur_count = 0;
        Ok(())
    }

    pub fn append(&mut self, sample: &[u8]) -> Result<()> {
        if self.cur_count >= self.cur_capacity() {
            self.roll()?;
        }
        if self.cur.is_none() {
            let path = self.dir.join(Self::shard_file(self.shards.len()));
            self.cur = Some(ShdfWriter::create_with_codec(&path, self.header.clone(), self.codec)?);
        }
        self.cur.as_mut().expect("shard writer just ensured").append(sample)?;
        self.cur_count += 1;
        self.total += 1;
        Ok(())
    }

    pub fn append_f32(&mut self, sample: &[f32]) -> Result<()> {
        if sample.len() * 4 != self.header.sample_bytes {
            bail!("sample is {} f32s, expected {}", sample.len(), self.header.sample_bytes / 4);
        }
        self.append(&crate::storage::store::encode_f32(sample))
    }

    /// Close the open shard and write the manifest. Returns the manifest.
    pub fn finish(mut self) -> Result<ShardManifest> {
        self.roll()?;
        let manifest = ShardManifest {
            name: self.header.name.clone(),
            sample_bytes: self.header.sample_bytes,
            shape: self.header.shape.clone(),
            dtype: self.header.dtype.clone(),
            n_samples: self.total,
            shards: self.shards.clone(),
            codec: self.codec,
        };
        manifest.save(&self.dir)?;
        Ok(manifest)
    }
}

/// Read side of a sharded dataset: one open [`ShdfReader`] per shard,
/// global id → (shard, local id) via prefix sums.
#[derive(Debug)]
pub struct ShardedStore {
    name: String,
    shape: Vec<usize>,
    sample_bytes: usize,
    shards: Vec<ShdfReader>,
    /// Prefix sums: `starts[k]` = global id of shard k's first sample;
    /// `starts[len] = n_samples`.
    starts: Vec<usize>,
    /// Virtual byte address of each shard's byte 0 in the notional
    /// concatenation of the shard files (for the contiguity map).
    bases: Vec<u64>,
    codec: Codec,
}

impl ShardedStore {
    pub fn open(dir: &Path) -> Result<ShardedStore> {
        let m = ShardManifest::load(dir)?;
        let mut shards = Vec::with_capacity(m.shards.len());
        let mut starts = vec![0usize];
        let mut bases = Vec::with_capacity(m.shards.len());
        let mut base = 0u64;
        for (file, n) in &m.shards {
            let path = dir.join(file);
            let r = ShdfReader::open(&path)?;
            let h = r.header();
            if h.n_samples != *n {
                bail!(
                    "shard {} holds {} samples, manifest says {n}",
                    path.display(),
                    h.n_samples
                );
            }
            if h.sample_bytes != m.sample_bytes || h.shape != m.shape || h.dtype != m.dtype {
                bail!(
                    "shard {} layout ({} B, {:?}, {}) disagrees with manifest ({} B, {:?}, {})",
                    path.display(),
                    h.sample_bytes,
                    h.shape,
                    h.dtype,
                    m.sample_bytes,
                    m.shape,
                    m.dtype
                );
            }
            if h.name != m.name {
                // A same-shaped shard from a DIFFERENT dataset must not
                // open cleanly — it would silently serve wrong bytes.
                bail!(
                    "shard {} belongs to dataset '{}', manifest is for '{}'",
                    path.display(),
                    h.name,
                    m.name
                );
            }
            if r.codec() != m.codec {
                // Codec is negotiated once for the whole dataset; a shard
                // encoded differently would be mis-decoded.
                bail!(
                    "shard {} uses codec '{}', manifest says '{}'",
                    path.display(),
                    r.codec().name(),
                    m.codec.name()
                );
            }
            starts.push(starts.last().unwrap() + n);
            bases.push(base);
            // Advance by the shard's true on-disk payload footprint: the
            // encoded extent span when compressed, the uniform stride
            // otherwise.
            let payload = match r.extent_index() {
                Some(idx) => idx[*n] - idx[0],
                None => *n as u64 * m.sample_bytes as u64,
            };
            base += r.offset_of(0) + payload;
            shards.push(r);
        }
        Ok(ShardedStore {
            name: m.name,
            shape: m.shape,
            sample_bytes: m.sample_bytes,
            shards,
            starts,
            bases,
            codec: m.codec,
        })
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Shard index holding global sample `i` (the last shard whose start
    /// is ≤ i — empty shards are skipped naturally).
    fn shard_of(&self, i: usize) -> usize {
        debug_assert!(i < *self.starts.last().unwrap());
        self.starts.partition_point(|&s| s <= i) - 1
    }
}

impl SampleStore for ShardedStore {
    fn n_samples(&self) -> usize {
        *self.starts.last().unwrap()
    }

    fn sample_bytes(&self) -> usize {
        self.sample_bytes
    }

    fn shape(&self) -> &[usize] {
        &self.shape
    }

    fn dataset_name(&self) -> &str {
        &self.name
    }

    fn read_sample_into_at(&self, i: usize, buf: &mut [u8]) -> Result<()> {
        let n = SampleStore::n_samples(self);
        if i >= n {
            bail!("sample index {i} out of range ({n} samples)");
        }
        let k = self.shard_of(i);
        self.shards[k].read_sample_into_at(i - self.starts[k], buf)
    }

    fn read_range_into_at(&self, start: usize, count: usize, buf: &mut [u8]) -> Result<()> {
        if start + count > SampleStore::n_samples(self) {
            bail!("range [{start}, {}) out of range", start + count);
        }
        assert_eq!(buf.len(), count * self.sample_bytes);
        if count == 0 {
            return Ok(());
        }
        // A range may span shard boundaries (callers that follow the
        // contiguity hint never ask for one, but the read stays correct
        // regardless): split into per-shard sub-ranges.
        let mut pos = start;
        let mut done = 0usize;
        while done < count {
            let k = self.shard_of(pos);
            let shard_end = self.starts[k + 1];
            let take = (count - done).min(shard_end - pos);
            let lo = done * self.sample_bytes;
            let hi = (done + take) * self.sample_bytes;
            self.shards[k].read_range_into_at(pos - self.starts[k], take, &mut buf[lo..hi])?;
            pos += take;
            done += take;
        }
        Ok(())
    }

    fn chunk_contiguity(&self) -> Contiguity {
        let mut regions = Vec::with_capacity(self.shards.len());
        // Variable extents (compressed layout): per-sample virtual
        // offsets plus each region's payload end, both rebased into the
        // concatenated address space.
        let mut var = VarExtents { offsets: Vec::new(), region_ends: Vec::new() };
        for (k, r) in self.shards.iter().enumerate() {
            let n = ShdfReader::n_samples(r);
            if n == 0 {
                continue; // empty shard: no addressable region
            }
            // Checked narrowing (lint R6): sample ids are u32 by format
            // contract; a shard starting beyond u32::MAX is a corrupt
            // manifest, not an id to wrap.
            let first_id = u32::try_from(self.starts[k]).expect("shard start exceeds u32 id space");
            regions.push((first_id, self.bases[k] + r.offset_of(0)));
            if let Some(idx) = r.extent_index() {
                var.offsets.extend(idx[..n].iter().map(|&o| self.bases[k] + o));
                var.region_ends.push(self.bases[k] + idx[n]);
            }
        }
        let c = Contiguity::from_regions(regions, self.sample_bytes);
        if self.codec.is_raw() {
            c
        } else {
            c.with_var_extents(Arc::new(var))
        }
    }

    fn codec(&self) -> Codec {
        self.codec
    }

    fn read_span_raw_at(&self, start: usize, count: usize, buf: &mut Vec<u8>) -> Result<()> {
        if start + count > SampleStore::n_samples(self) {
            bail!("range [{start}, {}) out of range", start + count);
        }
        if count == 0 {
            buf.clear();
            return Ok(());
        }
        let k = self.shard_of(start);
        if start + count <= self.starts[k + 1] {
            // The common case — chunk aggregation never bridges shards.
            return self.shards[k].read_span_raw_at(start - self.starts[k], count, buf);
        }
        // Cross-shard span: concatenate per-shard spans (extents stay
        // decodable in sequence). Correct but off the hot path.
        buf.clear();
        let mut pos = start;
        let mut tmp = Vec::new();
        while pos < start + count {
            let k = self.shard_of(pos);
            let take = (start + count - pos).min(self.starts[k + 1] - pos);
            self.shards[k].read_span_raw_at(pos - self.starts[k], take, &mut tmp)?;
            buf.extend_from_slice(&tmp);
            pos += take;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::store::decode_f32;

    fn tmpdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("solar_shard_tests").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn header(elems: usize) -> ShdfHeader {
        ShdfHeader {
            n_samples: 0,
            sample_bytes: elems * 4,
            shape: vec![elems],
            dtype: "f32".into(),
            name: "sharded-test".into(),
        }
    }

    fn sample(i: usize, elems: usize) -> Vec<f32> {
        (0..elems).map(|j| (i * 1000 + j) as f32).collect()
    }

    fn write_sharded(dir: &Path, n: usize, elems: usize, cap: usize) -> ShardManifest {
        let mut w = ShardedWriter::create(dir, header(elems), cap).unwrap();
        for i in 0..n {
            w.append_f32(&sample(i, elems)).unwrap();
        }
        w.finish().unwrap()
    }

    #[test]
    fn writer_rolls_shards_and_manifest_adds_up() {
        let dir = tmpdir("roll");
        let m = write_sharded(&dir, 23, 4, 10);
        assert_eq!(m.n_samples, 23);
        assert_eq!(
            m.shards,
            vec![
                ("shard_00000.shdf".into(), 10),
                ("shard_00001.shdf".into(), 10),
                ("shard_00002.shdf".into(), 3)
            ]
        );
        // Manifest round-trips through JSON.
        let m2 = ShardManifest::load(&dir).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn balanced_writer_produces_exactly_n_shards() {
        let dir = tmpdir("balanced");
        let mut w = ShardedWriter::create_balanced(&dir, header(4), 6, 4).unwrap();
        for i in 0..6 {
            w.append_f32(&sample(i, 4)).unwrap();
        }
        let m = w.finish().unwrap();
        assert_eq!(
            m.shards.iter().map(|(_, n)| *n).collect::<Vec<_>>(),
            vec![2, 2, 1, 1],
            "sizes differ by at most one, tail never collapses"
        );
        // More shards than samples: capped at one sample per shard.
        let dir2 = tmpdir("balanced_tiny");
        let mut w = ShardedWriter::create_balanced(&dir2, header(4), 2, 8).unwrap();
        for i in 0..2 {
            w.append_f32(&sample(i, 4)).unwrap();
        }
        assert_eq!(w.finish().unwrap().shards.len(), 2);
    }

    #[test]
    fn balanced_sizes_split_evenly() {
        assert_eq!(ShardedWriter::balanced_sizes(6, 4), vec![2, 2, 1, 1]);
        assert_eq!(ShardedWriter::balanced_sizes(2, 8), vec![1, 1]);
        assert_eq!(ShardedWriter::balanced_sizes(10, 1), vec![10]);
        assert_eq!(ShardedWriter::balanced_sizes(10, 3), vec![4, 3, 3]);
    }

    #[test]
    fn global_reads_match_generation() {
        let dir = tmpdir("reads");
        write_sharded(&dir, 23, 4, 10);
        let s = ShardedStore::open(&dir).unwrap();
        assert_eq!(SampleStore::n_samples(&s), 23);
        assert_eq!(s.n_shards(), 3);
        for i in [0usize, 9, 10, 19, 20, 22] {
            assert_eq!(decode_f32(&s.read_sample_at(i).unwrap()), sample(i, 4), "sample {i}");
        }
        assert!(s.read_sample_at(23).is_err());
    }

    #[test]
    fn range_reads_span_shard_boundaries() {
        let dir = tmpdir("range");
        write_sharded(&dir, 23, 4, 10);
        let s = ShardedStore::open(&dir).unwrap();
        // [8, 13): crosses the shard 0 → 1 boundary.
        let bytes = s.read_range_at(8, 5).unwrap();
        for (k, i) in (8..13).enumerate() {
            assert_eq!(decode_f32(&bytes[k * 16..(k + 1) * 16]), sample(i, 4), "sample {i}");
        }
        // Whole dataset in one call (crosses both boundaries).
        let all = s.read_range_at(0, 23).unwrap();
        assert_eq!(decode_f32(&all[22 * 16..]), sample(22, 4));
        assert!(s.read_range_at(20, 4).is_err());
        assert!(s.read_range_into_at(23, 0, &mut []).is_ok());
    }

    #[test]
    fn contiguity_has_one_region_per_shard() {
        let dir = tmpdir("contig");
        write_sharded(&dir, 23, 4, 10);
        let s = ShardedStore::open(&dir).unwrap();
        let c = s.chunk_contiguity();
        assert_eq!(c.n_regions(), 3);
        assert_eq!(c.region_end(0), 10);
        assert_eq!(c.region_end(10), 20);
        assert_eq!(c.region_end(20), u32::MAX);
        // Within a region offsets advance by sample_bytes; across the
        // boundary they jump by more (the next file's header region).
        assert_eq!(c.offset_of(1) - c.offset_of(0), 16);
        assert!(c.offset_of(10) - c.offset_of(9) > 16);
    }

    #[test]
    fn open_rejects_manifest_shard_mismatch() {
        let dir = tmpdir("mismatch");
        write_sharded(&dir, 23, 4, 10);
        // Tamper: manifest claims a different count for shard 1.
        let mut m = ShardManifest::load(&dir).unwrap();
        m.shards[1].1 = 9;
        m.n_samples = 22;
        m.save(&dir).unwrap();
        assert!(ShardedStore::open(&dir).is_err());
    }

    #[test]
    fn open_rejects_shard_from_another_dataset() {
        // Same shape/dtype/count, different dataset name: a swapped-in
        // shard must fail loudly, not silently serve wrong bytes.
        let dir = tmpdir("swapname");
        write_sharded(&dir, 23, 4, 10);
        let other = tmpdir("swapname_other");
        let mut h = header(4);
        h.name = "some-other-dataset".into();
        let mut w = ShardedWriter::create(&other, h, 10).unwrap();
        for i in 0..10 {
            w.append_f32(&sample(i, 4)).unwrap();
        }
        w.finish().unwrap();
        std::fs::copy(other.join("shard_00000.shdf"), dir.join("shard_00001.shdf")).unwrap();
        let err = ShardedStore::open(&dir).unwrap_err();
        assert!(format!("{err:#}").contains("belongs to dataset"), "{err:#}");
    }

    #[test]
    fn open_rejects_missing_shard_file() {
        let dir = tmpdir("missing");
        write_sharded(&dir, 23, 4, 10);
        std::fs::remove_file(dir.join("shard_00001.shdf")).unwrap();
        assert!(ShardedStore::open(&dir).is_err());
    }

    #[test]
    fn open_rejects_missing_manifest() {
        let dir = tmpdir("nomanifest");
        assert!(ShardedStore::open(&dir).is_err());
    }

    fn write_sharded_codec(dir: &Path, n: usize, elems: usize, cap: usize) -> ShardManifest {
        let mut w =
            ShardedWriter::create_with_codec(dir, header(elems), cap, Codec::DeltaBitpack).unwrap();
        for i in 0..n {
            w.append_f32(&sample(i, elems)).unwrap();
        }
        w.finish().unwrap()
    }

    #[test]
    fn compressed_sharded_dataset_roundtrips() {
        let dir = tmpdir("codec_roundtrip");
        let m = write_sharded_codec(&dir, 23, 16, 10);
        assert_eq!(m.codec, Codec::DeltaBitpack);
        assert_eq!(ShardManifest::load(&dir).unwrap(), m);
        let s = ShardedStore::open(&dir).unwrap();
        assert_eq!(SampleStore::codec(&s), Codec::DeltaBitpack);
        for i in [0usize, 9, 10, 19, 22] {
            assert_eq!(decode_f32(&s.read_sample_at(i).unwrap()), sample(i, 16), "sample {i}");
        }
        // Cross-boundary decoded range read still works.
        let bytes = s.read_range_at(8, 5).unwrap();
        for (k, i) in (8..13).enumerate() {
            assert_eq!(decode_f32(&bytes[k * 64..(k + 1) * 64]), sample(i, 16), "sample {i}");
        }
    }

    #[test]
    fn raw_manifest_has_no_codec_key() {
        let dir = tmpdir("codec_absent");
        write_sharded(&dir, 5, 4, 5);
        let text = std::fs::read_to_string(dir.join(MANIFEST)).unwrap();
        assert!(!text.contains("codec"), "{text}");
        assert!(ShardManifest::load(&dir).unwrap().codec.is_raw());
    }

    #[test]
    fn manifest_rejects_unknown_codec() {
        let dir = tmpdir("codec_unknown");
        let mut j = write_sharded(&dir, 5, 4, 5).to_json();
        j.set("codec", Json::Str("bogus".into()));
        let err = ShardManifest::from_json(&j).unwrap_err();
        assert!(format!("{err:#}").contains("unsupported codec"), "{err:#}");
    }

    #[test]
    fn open_rejects_shard_codec_mismatch() {
        // A raw shard swapped into a compressed dataset must fail loudly.
        let dir = tmpdir("codec_mismatch");
        write_sharded_codec(&dir, 23, 4, 10);
        let other = tmpdir("codec_mismatch_raw");
        write_sharded(&other, 23, 4, 10);
        std::fs::copy(other.join("shard_00001.shdf"), dir.join("shard_00001.shdf")).unwrap();
        let err = ShardedStore::open(&dir).unwrap_err();
        assert!(format!("{err:#}").contains("uses codec"), "{err:#}");
    }

    #[test]
    fn compressed_contiguity_reports_var_extents() {
        let dir = tmpdir("codec_contig");
        write_sharded_codec(&dir, 23, 16, 10);
        let s = ShardedStore::open(&dir).unwrap();
        let c = s.chunk_contiguity();
        assert_eq!(c.n_regions(), 3);
        assert!(c.is_var());
        // Offsets are monotone, and a full-shard span is smaller than the
        // raw stride (these low-entropy ramps compress).
        for i in 1..23u32 {
            assert!(c.offset_of(i) >= c.offset_of(i - 1), "sample {i}");
        }
        assert!(c.span_bytes(0, 10) < 10 * 64);
        // Spans match the raw bytes the store actually serves.
        let mut raw = Vec::new();
        s.read_span_raw_at(10, 10, &mut raw).unwrap();
        assert_eq!(raw.len() as u64, c.span_bytes(10, 10));
        // Cross-shard raw span concatenates per-shard extents.
        let mut x = Vec::new();
        s.read_span_raw_at(8, 5, &mut x).unwrap();
        assert_eq!(x.len() as u64, c.span_bytes(8, 2) + c.span_bytes(10, 3));
    }

    #[test]
    fn manifest_rejects_bad_totals_and_format() {
        let dir = tmpdir("badmanifest");
        let mut m = write_sharded(&dir, 5, 4, 5);
        m.n_samples = 99;
        let j = m.to_json();
        assert!(ShardManifest::from_json(&j).is_err());
        let mut j2 = write_sharded(&tmpdir("badfmt"), 5, 4, 5).to_json();
        j2.set("format", Json::Str("something-else".into()));
        assert!(ShardManifest::from_json(&j2).is_err());
    }
}
