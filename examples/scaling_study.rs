//! Fig 2-style scaling study on the REAL training driver: epoch wall time
//! at 1/2/4 worker nodes (unthrottled, so computation is visible), plus
//! the simulated weak-scaling breakdown of Fig 3.
//!
//! ```bash
//! make artifacts && cargo run --release --example scaling_study
//! ```

use std::path::PathBuf;

use solar::config::RunConfig;
use solar::data::spec::DatasetSpec;
use solar::data::synth;
use solar::dist::sim::simulate;
use solar::exp::ExpCtx;
use solar::loader::LoaderPolicy;
use solar::runtime::executable::DenseImpl;
use solar::storage::pfs::CostModel;
use solar::storage::store::{open_store, SampleStore};
use solar::train::driver::{train, FaultKind, PrefetchMode, TrainConfig};
use solar::util::fmt_secs;
use solar::util::stats::TextTable;

fn main() -> anyhow::Result<()> {
    let artifacts = PathBuf::from("artifacts");
    let n_train = 512;

    if artifacts.join("manifest.json").exists() && solar::runtime::pjrt_available() {
        // Real-driver strong scaling.
        let dir = PathBuf::from("results/data");
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("cd_scaling_{n_train}.shdf"));
        let mut spec = DatasetSpec::paper("cd17").unwrap();
        spec.id = format!("cd_scaling_{n_train}");
        spec.n_samples = n_train;
        let ok = open_store(&path).map(|s| s.n_samples() == n_train).unwrap_or(false);
        if !ok {
            synth::generate_dataset(&path, &spec, 99)?;
        }
        let store = open_store(&path)?;
        let mut t = TextTable::new(&["#workers", "epoch wall", "compute", "load", "speedup"]);
        let mut base = None;
        for n_nodes in [1usize, 2, 4] {
            let cfg = RunConfig {
                spec: spec.clone(),
                n_nodes,
                local_batch: 16,
                n_epochs: 1,
                seed: 1,
                buffer_capacity: n_train,
                cost: CostModel::default(),
            };
            let tc = TrainConfig {
                run: cfg,
                store: store.clone(),
                artifacts_dir: artifacts.clone(),
                policy: LoaderPolicy::pytorch(),
                dense: DenseImpl::Xla,
                lr: 0.05,
                throttle: 0.0, // unthrottled: show compute scaling
                eval_every: 0,
                max_steps: 0,
                holdout: 0,
                prefetch: PrefetchMode::Fixed(1),
                epoch_drain: false,
                fetch_fault: None,
                fault_kind: FaultKind::Error,
                checkpoint_every: 0,
                checkpoint_path: None,
                resume: None,
                load_only: false,
                io_threads: 0, // auto: SOLAR_IO_THREADS or the machine default
            };
            let r = train(&tc)?;
            let b = *base.get_or_insert(r.total_wall_s);
            t.rowv(vec![
                format!("{n_nodes}"),
                fmt_secs(r.total_wall_s),
                fmt_secs(r.comp_wall_s),
                fmt_secs(r.load_wall_s),
                format!("{:.2}x", b / r.total_wall_s),
            ]);
        }
        println!("Fig 2-style: real-driver scaling (PJRT CPU workers, {n_train} samples)\n\n{}", t.render());
    } else {
        println!("(artifacts missing — skipping the real-driver scaling; run `make artifacts`)");
    }

    // Fig 3 weak-scaling breakdown (simulated).
    let ctx = ExpCtx::new(true);
    let mut t = TextTable::new(&["dataset", "#nodes", "load share"]);
    for ds in ["cd17", "bcdi", "cosmoflow"] {
        for n in [4usize, 16] {
            let mut cfg = ctx.run_config(ds, solar::storage::pfs::SystemTier::Low, 64)?;
            cfg.n_nodes = n;
            cfg.n_epochs = 3;
            let r = simulate(&cfg, &LoaderPolicy::pytorch());
            t.rowv(vec![
                ds.into(),
                format!("{n}"),
                format!("{:.1}%", 100.0 * r.avg_load_s() / (r.avg_load_s() + r.avg_comp_s())),
            ]);
        }
    }
    println!("\nFig 3-style: loading share grows under weak scaling (simulated)\n\n{}", t.render());
    Ok(())
}
