//! Table 3: I/O time of the four HDF5 access patterns (§4.4) — modeled on
//! the calibrated PFS cost model, plus (optionally) measured against a real
//! SHDF file via `examples/io_patterns.rs`.

use anyhow::Result;

use crate::exp::ExpCtx;
use crate::storage::access::{modeled_parallel_time, AccessPattern};
use crate::util::stats::TextTable;

pub fn tab3_access_patterns(ctx: &ExpCtx) -> Result<()> {
    // Always full scale: the analytic model is free, and the random-access
    // seek distances (hence the 203x gap) depend on the real dataset size.
    let spec = crate::data::spec::DatasetSpec::paper("cd17").unwrap();
    let n_procs = 4;
    let times: Vec<(AccessPattern, f64)> = AccessPattern::all()
        .into_iter()
        .map(|p| {
            (p, modeled_parallel_time(spec.n_samples, spec.sample_bytes, n_procs, p, &crate::storage::pfs::CostModel::default(), ctx.seed))
        })
        .collect();
    let full = times.iter().find(|(p, _)| *p == AccessPattern::FullChunk).unwrap().1;
    let random = times.iter().find(|(p, _)| *p == AccessPattern::Random).unwrap().1;
    let mut t = TextTable::new(&["Pattern", "Time (s)", "Norm'ed", "Speedup"]);
    for (p, time) in &times {
        t.rowv(vec![
            p.name().into(),
            format!("{time:.3}"),
            format!("{:.2}x", time / full),
            format!("{:.2}x", random / time),
        ]);
    }
    let text = format!(
        "Table 3 — modeled I/O time of the four access patterns over the\n\
         CD dataset ({} samples x {} KB, {n_procs} reader processes).\n\
         Paper: 645.9 / 84.4 / 30.5 / 3.18 s — full-chunk 203x over random.\n\
         (Measured-on-disk variant: `cargo run --release --example io_patterns`.)\n\n{}",
        spec.n_samples,
        spec.sample_bytes / 1024,
        t.render()
    );
    ctx.emit("tab3", &text)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tab3_reproduces_ordering_and_gap() {
        let mut ctx = ExpCtx::new(true);
        ctx.out_dir = std::env::temp_dir().join("solar_exp_io");
        tab3_access_patterns(&ctx).unwrap();
        let text = std::fs::read_to_string(ctx.out_dir.join("tab3.txt")).unwrap();
        // Table rows exist for all four patterns.
        for p in AccessPattern::all() {
            assert!(text.contains(p.name()), "{}", p.name());
        }
    }
}
