//! Dataset specifications mirroring the paper's five evaluation datasets
//! (§5.1), plus scaled-down variants for real-bytes runs.
//!
//! The trace-driven simulator only needs (#samples, sample size); the
//! real-bytes mode (`gen-data` + end-to-end training) materializes a scaled
//! synthetic SHDF container with the same per-sample shape.

use crate::storage::pfs::SystemTier;

/// A dataset described by its loading-relevant parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct DatasetSpec {
    /// Short id, e.g. "cd17".
    pub id: String,
    /// Human name matching the paper.
    pub name: String,
    /// Number of samples.
    pub n_samples: usize,
    /// Bytes per sample (one training record).
    pub sample_bytes: usize,
    /// Logical shape of one record as stored (f32 elements).
    pub shape: Vec<usize>,
    /// Which surrogate trains on it (for compute-time modeling).
    pub model: SurrogateModel,
}

/// The three surrogate models benchmarked in the paper.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SurrogateModel {
    PtychoNN,
    AutoPhaseNN,
    CosmoFlow,
}

impl SurrogateModel {
    pub fn name(&self) -> &'static str {
        match self {
            SurrogateModel::PtychoNN => "PtychoNN",
            SurrogateModel::AutoPhaseNN => "AutoPhaseNN",
            SurrogateModel::CosmoFlow => "CosmoFlow",
        }
    }

    /// Modeled per-sample fwd+bwd compute time on one device, seconds.
    /// Calibrated against Fig 3's 4-GPU breakdown (loading = 83.1% / 77.3%
    /// / 43.2% for PtychoNN / AutoPhaseNN / CosmoFlow) given the calibrated
    /// PFS model's per-sample loading costs; Table 1's higher loading share
    /// on the 1.2 TB set then falls out of the larger seek distances.
    pub fn compute_per_sample_s(&self) -> f64 {
        match self {
            SurrogateModel::PtychoNN => 0.264e-3,
            SurrogateModel::AutoPhaseNN => 1.14e-3, // 3D CNN on 3.1 MB samples
            SurrogateModel::CosmoFlow => 11.2e-3,   // 3D CNN on 17 MB samples
        }
    }
}

impl DatasetSpec {
    pub fn total_bytes(&self) -> u64 {
        self.n_samples as u64 * self.sample_bytes as u64
    }

    /// The five paper datasets at full scale (for trace simulation).
    pub fn paper(id: &str) -> Option<DatasetSpec> {
        // CD sample: 65 KB image (the paper's Coherent Diffraction data).
        // Our record shape for CD is [4, 64, 64] f32 = 64 KiB ≈ the paper's
        // 65 KB per image (diffraction + amplitude + phase + pad channel).
        let cd_shape = vec![4, 64, 64];
        let cd_bytes = 4 * 64 * 64 * 4;
        Some(match id {
            "cd17" => DatasetSpec {
                id: "cd17".into(),
                name: "CD 17 GB".into(),
                n_samples: 262_896,
                sample_bytes: cd_bytes,
                shape: cd_shape,
                model: SurrogateModel::PtychoNN,
            },
            // NOTE: the paper says the synthesized 321 GB set has 1,752,660
            // samples, but 1,752,660 × 65 KB ≈ 114 GB — internally
            // inconsistent. Buffer behaviour depends on the byte volume, so
            // we derive the count from the stated 321 GB instead.
            "cd321" => DatasetSpec {
                id: "cd321".into(),
                name: "CD 321 GB".into(),
                n_samples: 4_897_280,
                sample_bytes: cd_bytes,
                shape: cd_shape,
                model: SurrogateModel::PtychoNN,
            },
            "cd1200" => DatasetSpec {
                id: "cd1200".into(),
                name: "CD 1.2 TB".into(),
                n_samples: 18_928_620,
                sample_bytes: cd_bytes,
                shape: cd_shape,
                model: SurrogateModel::PtychoNN,
            },
            "bcdi" => DatasetSpec {
                id: "bcdi".into(),
                name: "BCDI 151 GB".into(),
                n_samples: 54_030,
                sample_bytes: 3_145_728, // 3.1 MB ≈ [3, 64, 64, 64] f32
                shape: vec![3, 64, 64, 64],
                model: SurrogateModel::AutoPhaseNN,
            },
            "cosmoflow" => DatasetSpec {
                id: "cosmoflow".into(),
                name: "CosmoFlow 1 TB".into(),
                n_samples: 63_808,
                sample_bytes: 16_777_216, // 17 MB ≈ [4, 128, 128, 64] f32
                shape: vec![4, 128, 128, 64],
                model: SurrogateModel::CosmoFlow,
            },
            _ => return None,
        })
    }

    pub fn paper_ids() -> [&'static str; 5] {
        ["cd17", "cd321", "cd1200", "bcdi", "cosmoflow"]
    }

    /// A scaled variant: same per-sample shape/size, `1/factor` as many
    /// samples (floored, min 1). Used for real-bytes runs.
    pub fn scaled(&self, factor: usize) -> DatasetSpec {
        let mut s = self.clone();
        s.id = format!("{}_s{}", self.id, factor);
        s.name = format!("{} (1/{factor} scale)", self.name);
        s.n_samples = (self.n_samples / factor).max(1);
        s
    }

    /// Number of nodes (one GPU per node, as in §5.2) the paper uses for
    /// this dataset on each system tier — Table 4.
    pub fn paper_nodes(&self, tier: SystemTier) -> usize {
        let base_id = self.id.split("_s").next().unwrap_or(&self.id);
        match (base_id, tier) {
            ("cd17", _) => 2,
            ("cd321", SystemTier::High) => 8,
            ("cd321", _) => 16,
            ("cd1200", SystemTier::High) => 16,
            ("cd1200", _) => 32,
            ("bcdi", _) => 8,
            ("cosmoflow", _) => 16,
            _ => 8,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_dataset_sizes_are_close_to_reported() {
        let close = |spec: &str, gb: f64, tol: f64| {
            let s = DatasetSpec::paper(spec).unwrap();
            let actual = s.total_bytes() as f64 / 1e9;
            assert!((actual - gb).abs() / gb < tol, "{spec}: {actual} GB vs paper {gb} GB");
        };
        close("cd17", 17.0, 0.05);
        close("cd321", 321.0, 0.15);
        close("cd1200", 1200.0, 0.15);
        close("bcdi", 151.0, 0.20);
        close("cosmoflow", 1000.0, 0.15);
    }

    #[test]
    fn unknown_id_is_none() {
        assert!(DatasetSpec::paper("nope").is_none());
    }

    #[test]
    fn scaled_preserves_sample_size() {
        let s = DatasetSpec::paper("cd17").unwrap();
        let t = s.scaled(100);
        assert_eq!(t.sample_bytes, s.sample_bytes);
        assert_eq!(t.n_samples, s.n_samples / 100);
        assert!(t.id.contains("_s100"));
    }

    #[test]
    fn table4_node_counts() {
        let cd321 = DatasetSpec::paper("cd321").unwrap();
        assert_eq!(cd321.paper_nodes(SystemTier::Low), 16);
        assert_eq!(cd321.paper_nodes(SystemTier::High), 8);
        let cd1200 = DatasetSpec::paper("cd1200").unwrap();
        assert_eq!(cd1200.paper_nodes(SystemTier::Medium), 32);
        assert_eq!(cd1200.paper_nodes(SystemTier::High), 16);
        // Scaled variants inherit the parent's node counts.
        assert_eq!(cd321.scaled(10).paper_nodes(SystemTier::Low), 16);
    }

    #[test]
    fn compute_costs_ordered_by_model_size() {
        assert!(
            SurrogateModel::PtychoNN.compute_per_sample_s()
                < SurrogateModel::AutoPhaseNN.compute_per_sample_s()
        );
        assert!(
            SurrogateModel::AutoPhaseNN.compute_per_sample_s()
                < SurrogateModel::CosmoFlow.compute_per_sample_s()
        );
    }
}
