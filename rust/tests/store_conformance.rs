//! Shared conformance suite for every [`SampleStore`] backend: the
//! single-file SHDF container, the sharded dataset directory, and the
//! in-memory store are generated from the SAME spec/seed and must be
//! byte-for-byte interchangeable — same reads, same errors, same
//! concurrency guarantees — and must drive the training pipeline to the
//! same schedule (checked here via the driver's PJRT-free `load_only`
//! mode, so this runs everywhere; the full bit-identity of trained
//! params lives in `driver_pipeline_parity.rs`, which needs artifacts).

use std::path::PathBuf;
use std::sync::Arc;

use solar::config::RunConfig;
use solar::data::spec::DatasetSpec;
use solar::data::synth;
use solar::loader::LoaderPolicy;
use solar::runtime::executable::DenseImpl;
use solar::storage::codec::Codec;
use solar::storage::fault::{FaultPlan, FaultyStore};
use solar::storage::pfs::CostModel;
use solar::storage::store::{decode_f32, open_store, SampleStore};
use solar::train::driver::{train, PrefetchMode, TrainConfig};
use solar::util::rng::Rng;

const N: usize = 56;
const SEED: u64 = 1234;

fn spec() -> DatasetSpec {
    let mut s = DatasetSpec::paper("cd17").unwrap();
    s.n_samples = N;
    s.id = "conformance".into();
    s
}

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("solar_store_conformance");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// The five backends over identical decoded samples, labeled: raw
/// single-file, raw sharded, in-memory, plus the delta-bitpack twins of
/// the on-disk layouts (same spec/seed — only the on-disk bytes differ).
/// Generation runs at most once per process (tests share these fixtures
/// and run in parallel; concurrent writers to one path would corrupt it).
fn backends() -> Vec<(&'static str, Arc<dyn SampleStore>)> {
    static GEN: std::sync::OnceLock<()> = std::sync::OnceLock::new();
    GEN.get_or_init(|| {
        let spec = spec();
        let single = tmp("single.shdf");
        let ok = open_store(&single).map(|s| s.n_samples() == N).unwrap_or(false);
        if !ok {
            synth::generate_dataset(&single, &spec, SEED).unwrap();
        }
        let sharded = tmp("sharded");
        let ok = open_store(&sharded).map(|s| s.n_samples() == N).unwrap_or(false);
        if !ok {
            let _ = std::fs::remove_dir_all(&sharded);
            synth::generate_dataset_sharded(&sharded, &spec, SEED, 3).unwrap();
        }
        let single_dbp = tmp("single_dbp.shdf");
        let ok = open_store(&single_dbp).map(|s| s.n_samples() == N).unwrap_or(false);
        if !ok {
            synth::generate_dataset_with(&single_dbp, &spec, SEED, Codec::DeltaBitpack).unwrap();
        }
        let sharded_dbp = tmp("sharded_dbp");
        let ok = open_store(&sharded_dbp).map(|s| s.n_samples() == N).unwrap_or(false);
        if !ok {
            let _ = std::fs::remove_dir_all(&sharded_dbp);
            synth::generate_dataset_sharded_workers_with(
                &sharded_dbp,
                &spec,
                SEED,
                3,
                2,
                Codec::DeltaBitpack,
            )
            .unwrap();
        }
    });
    vec![
        ("single-file", open_store(&tmp("single.shdf")).unwrap()),
        ("sharded", open_store(&tmp("sharded")).unwrap()),
        ("in-memory", Arc::new(synth::generate_dataset_mem(&spec(), SEED))),
        ("single-file-dbp", open_store(&tmp("single_dbp.shdf")).unwrap()),
        ("sharded-dbp", open_store(&tmp("sharded_dbp")).unwrap()),
    ]
}

/// Ground truth: record `i` exactly as the generator produces it.
fn expected(i: usize) -> Vec<f32> {
    synth::generate_record(&mut Rng::new(SEED).fork(i as u64))
}

#[test]
fn all_backends_serve_identical_metadata_and_bytes() {
    for (name, store) in backends() {
        assert_eq!(store.n_samples(), N, "{name}");
        assert_eq!(store.sample_bytes(), 4 * 64 * 64 * 4, "{name}");
        assert_eq!(store.shape(), &[4, 64, 64], "{name}");
        assert_eq!(store.dataset_name(), "conformance", "{name}");
        for i in [0usize, 1, 17, 18, 19, 37, 38, N - 1] {
            let got = decode_f32(&store.read_sample_at(i).unwrap());
            assert_eq!(got, expected(i), "{name}: sample {i}");
        }
    }
}

#[test]
fn range_reads_match_per_sample_reads_everywhere() {
    for (name, store) in backends() {
        let sb = store.sample_bytes();
        // [17, 23): crosses the 3-shard layout's first boundary (shards
        // of ceil(56/3)=19 samples: 19+19+18).
        for (start, count) in [(0usize, 5usize), (17, 6), (36, 4), (0, N), (N - 1, 1)] {
            let bytes = store.read_range_at(start, count).unwrap();
            assert_eq!(bytes.len(), count * sb, "{name}");
            for k in 0..count {
                assert_eq!(
                    decode_f32(&bytes[k * sb..(k + 1) * sb]),
                    expected(start + k),
                    "{name}: range [{start},+{count}) sample {k}"
                );
            }
        }
    }
}

#[test]
fn out_of_range_and_zero_length_semantics_agree() {
    for (name, store) in backends() {
        assert!(store.read_sample_at(N).is_err(), "{name}: sample N must error");
        assert!(store.read_sample_at(N + 100).is_err(), "{name}");
        assert!(store.read_range_at(N - 1, 2).is_err(), "{name}: range past end must error");
        assert!(store.read_range_at(N, 1).is_err(), "{name}");
        // Zero-length reads: Ok up to and at the end, error past it.
        assert!(store.read_range_into_at(0, 0, &mut []).is_ok(), "{name}");
        assert!(store.read_range_into_at(N, 0, &mut []).is_ok(), "{name}");
        assert!(store.read_range_into_at(N + 1, 0, &mut []).is_err(), "{name}");
    }
}

#[test]
fn concurrent_reads_through_one_shared_handle() {
    // The trait contract the fetch/exec threads rely on: positioned reads
    // take &self and race-free through one shared handle.
    for (name, store) in backends() {
        let store: &dyn SampleStore = store.as_ref();
        std::thread::scope(|s| {
            for t in 0..4usize {
                s.spawn(move || {
                    for rep in 0..25 {
                        let i = (t * 13 + rep * 7) % N;
                        let got = decode_f32(&store.read_sample_at(i).unwrap());
                        assert_eq!(got, expected(i), "{name}: thread {t} sample {i}");
                    }
                });
            }
        });
    }
}

#[test]
fn contiguity_maps_describe_each_layout() {
    for (name, store) in backends() {
        let c = store.chunk_contiguity();
        if name.starts_with("sharded") {
            assert_eq!(c.n_regions(), 3, "{name}");
        } else {
            assert_eq!(c.n_regions(), 1, "{name}");
        }
        // Within a region, consecutive raw samples are sample_bytes
        // apart; compressed extents vary, but offsets never decrease
        // across the id space on any layout.
        let sb = store.sample_bytes() as u64;
        let raw = store.codec().is_raw();
        let mut prev = None;
        for i in 0..N as u32 {
            let off = c.offset_of(i);
            if let Some(p) = prev {
                assert!(off > p, "{name}: offsets must increase");
                if raw && c.region_end(i - 1) != i {
                    assert_eq!(off - p, sb, "{name}: contiguous inside a region");
                }
            }
            prev = Some(off);
        }
    }
}

#[test]
fn compressed_layouts_serve_identical_bytes_and_smaller_files() {
    let b = backends();
    let raw = &b[0].1; // single-file
    for (name, store) in &b[3..] {
        assert!(!store.codec().is_raw(), "{name}");
        for i in 0..N {
            assert_eq!(
                store.read_sample_at(i).unwrap(),
                raw.read_sample_at(i).unwrap(),
                "{name}: sample {i}"
            );
        }
        let bytes = store.read_range_at(0, N).unwrap();
        assert_eq!(bytes, raw.read_range_at(0, N).unwrap(), "{name}: full range");
    }
    // The compression is real: the encoded container is smaller than the
    // fixed-stride one.
    let raw_len = std::fs::metadata(tmp("single.shdf")).unwrap().len();
    let dbp_len = std::fs::metadata(tmp("single_dbp.shdf")).unwrap().len();
    assert!(dbp_len < raw_len, "dbp {dbp_len} vs raw {raw_len}");
}

#[test]
fn open_store_detects_layouts() {
    let _ = backends(); // ensure datasets exist
    let single = open_store(&tmp("single.shdf")).unwrap();
    let sharded = open_store(&tmp("sharded")).unwrap();
    assert_eq!(single.chunk_contiguity().n_regions(), 1);
    assert_eq!(sharded.chunk_contiguity().n_regions(), 3);
    assert!(open_store(&tmp("nope.shdf")).is_err());
}

#[test]
fn faulty_store_with_empty_plan_is_a_bitwise_passthrough_everywhere() {
    // The fault injector is a SampleStore like any other: with an empty
    // plan it must forward every method verbatim on every backend —
    // metadata, per-sample reads, range reads, and error semantics.
    for (name, store) in backends() {
        let faulty = FaultyStore::new(store.clone(), FaultPlan::default());
        assert_eq!(faulty.n_samples(), store.n_samples(), "{name}");
        assert_eq!(faulty.sample_bytes(), store.sample_bytes(), "{name}");
        assert_eq!(faulty.shape(), store.shape(), "{name}");
        assert_eq!(faulty.dataset_name(), store.dataset_name(), "{name}");
        assert_eq!(faulty.codec(), store.codec(), "{name}");
        assert_eq!(
            faulty.chunk_contiguity().n_regions(),
            store.chunk_contiguity().n_regions(),
            "{name}"
        );
        for i in [0usize, 17, 19, 37, N - 1] {
            assert_eq!(
                faulty.read_sample_at(i).unwrap(),
                store.read_sample_at(i).unwrap(),
                "{name}: sample {i}"
            );
        }
        for (start, count) in [(0usize, 5usize), (17, 6), (0, N)] {
            assert_eq!(
                faulty.read_range_at(start, count).unwrap(),
                store.read_range_at(start, count).unwrap(),
                "{name}: range [{start},+{count})"
            );
        }
        assert!(faulty.read_sample_at(N).is_err(), "{name}: inner bounds errors pass through");
        assert!(faulty.read_range_at(N - 1, 2).is_err(), "{name}");
    }
}

/// Load-only training config over a given store (no artifacts, no PJRT).
fn load_only_tc(store: Arc<dyn SampleStore>, loader: &str, prefetch: PrefetchMode) -> TrainConfig {
    let holdout = 8usize;
    let mut run_spec = spec();
    run_spec.n_samples = N - holdout;
    TrainConfig {
        run: RunConfig {
            spec: run_spec,
            n_nodes: 2,
            local_batch: 8,
            n_epochs: 2,
            seed: 9,
            buffer_capacity: 12,
            cost: CostModel::default(),
        },
        store,
        artifacts_dir: PathBuf::from("artifacts-not-needed"),
        policy: LoaderPolicy::by_name(loader).unwrap(),
        dense: DenseImpl::Xla,
        lr: 0.08,
        throttle: 0.0,
        eval_every: 0,
        max_steps: 0,
        holdout,
        prefetch,
        epoch_drain: false,
        fetch_fault: Vec::new(),
        fallback: false,
        checkpoint_every: 0,
        checkpoint_path: None,
        resume: None,
        load_only: true,
        io_threads: 0, // auto: SOLAR_IO_THREADS or the machine default
        plan: None,
        connect: None,
    }
}

#[test]
fn load_only_driver_runs_the_same_schedule_on_every_backend() {
    // The whole pipeline — plan → fetch threads → staging → buffer mirror
    // → batch assembly — against all three backends, no PJRT: step
    // counts, hit/fetch totals, and per-epoch stats must be identical.
    for loader in ["solar", "pytorch+lru"] {
        let mut reports = Vec::new();
        for (name, store) in backends() {
            let r = train(&load_only_tc(store, loader, PrefetchMode::Fixed(1))).unwrap();
            assert_eq!(r.steps, 2 * (48 / 16), "{name} {loader}");
            assert_eq!(r.epochs, 2, "{name} {loader}");
            assert!(r.points.iter().all(|p| p.train_loss == 0.0), "{name} {loader}");
            reports.push((name, r));
        }
        let (base_name, base) = &reports[0];
        for (name, r) in &reports[1..] {
            assert_eq!(base.steps, r.steps, "{base_name} vs {name} ({loader})");
            assert_eq!(base.hits, r.hits, "{base_name} vs {name} ({loader})");
            assert_eq!(base.pfs_samples, r.pfs_samples, "{base_name} vs {name} ({loader})");
            assert_eq!(base.epoch_stats, r.epoch_stats, "{base_name} vs {name} ({loader})");
        }
    }
}

#[test]
fn load_only_schedule_is_io_thread_invariant_on_every_backend() {
    // The parallel fetch pool moves bytes, never samples: at 1 vs 4 I/O
    // workers the schedule fingerprint must be identical on all three
    // backends (the sharded one exercises the per-shard grouping path).
    for (name, store) in backends() {
        let mk = |io: usize| {
            let mut c = load_only_tc(store.clone(), "solar", PrefetchMode::Fixed(1));
            c.io_threads = io;
            c
        };
        let base = train(&mk(1)).unwrap();
        let par = train(&mk(4)).unwrap();
        assert_eq!(base.steps, par.steps, "{name}");
        assert_eq!(base.hits, par.hits, "{name}");
        assert_eq!(base.pfs_samples, par.pfs_samples, "{name}");
        assert_eq!(base.epoch_stats, par.epoch_stats, "{name}");
    }
}

#[test]
fn load_only_schedule_is_fault_invariant_on_every_backend() {
    // Transient store faults exercise the fetch pool's retry/backoff on
    // every backend without perturbing the schedule: identical step
    // counts and hit/PFS totals to the bare store, with the retries
    // showing up only in the report's RetryStats.
    for (name, store) in backends() {
        let clean = train(&load_only_tc(store.clone(), "solar", PrefetchMode::Fixed(1))).unwrap();
        assert_eq!(clean.retry.retries, 0, "{name}: clean run must not retry");
        let plan = FaultPlan::parse("transient:5:2,transient:21:3,rate:0.1,seed:4").unwrap();
        let faulty: Arc<dyn SampleStore> = Arc::new(FaultyStore::new(store, plan));
        let r = train(&load_only_tc(faulty, "solar", PrefetchMode::Fixed(1))).unwrap();
        assert!(r.retry.retries > 0, "{name}: scripted faults must trigger retries");
        assert!(r.retry.attempts > r.retry.retries, "{name}");
        assert!(r.retry.backoff_us > 0, "{name}: retries charge backoff");
        assert_eq!(clean.steps, r.steps, "{name}");
        assert_eq!(clean.hits, r.hits, "{name}");
        assert_eq!(clean.pfs_samples, r.pfs_samples, "{name}");
        assert_eq!(clean.epoch_stats, r.epoch_stats, "{name}");
    }
}

#[test]
fn load_only_schedule_is_depth_invariant() {
    // Prefetch depth (including Auto) changes only timing; in load-only
    // mode the schedule fingerprint must stay fixed on every backend.
    let (_, store) = backends().remove(1); // sharded: the interesting layout
    let base = train(&load_only_tc(store.clone(), "solar", PrefetchMode::Fixed(0))).unwrap();
    for mode in [PrefetchMode::Fixed(2), PrefetchMode::Auto] {
        let r = train(&load_only_tc(store.clone(), "solar", mode)).unwrap();
        assert_eq!(base.steps, r.steps, "{mode}");
        assert_eq!(base.hits, r.hits, "{mode}");
        assert_eq!(base.pfs_samples, r.pfs_samples, "{mode}");
        assert_eq!(base.epoch_stats, r.epoch_stats, "{mode}");
    }
}
