//! Typed wrappers over the PJRT executables.
//!
//! The `xla` crate's handles hold raw pointers (not `Send`), so each worker
//! thread constructs its own [`TrainRuntime`] *inside* the thread (see
//! `train::driver`); the coordinator exchanges plain `Vec<f32>` tensors
//! with workers over channels. The offline build aliases the bindings to
//! [`crate::runtime::xla_stub`] (DESIGN.md §Substitutions).

use anyhow::{bail, Context, Result};
use std::path::Path;

use crate::runtime::manifest::Manifest;
use crate::runtime::params::ParamStore;
use crate::runtime::xla_stub as xla;

/// Which dense-layer implementation the loaded executable uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DenseImpl {
    /// L1 Pallas kernel (interpret-mode lowering) — the default.
    Pallas,
    /// Plain-XLA dense layers — the A/B comparison artifact.
    Xla,
}

impl DenseImpl {
    pub fn grads_key(&self) -> &'static str {
        match self {
            DenseImpl::Pallas => "grads",
            DenseImpl::Xla => "grads_xla",
        }
    }
}

/// One worker's compiled training-step (and optional forward) executable.
pub struct TrainRuntime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    grads_exe: xla::PjRtLoadedExecutable,
    fwd_exe: Option<xla::PjRtLoadedExecutable>,
}

/// Result of one training-step execution.
#[derive(Debug)]
pub struct StepOut {
    pub loss_sum: f32,
    /// Summed gradients, manifest parameter order.
    pub grads: Vec<Vec<f32>>,
}

fn compile(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(path)
        .with_context(|| format!("parse HLO text {}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    Ok(client.compile(&comp)?)
}

fn literal_from(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let elems: usize = shape.iter().product();
    if elems != data.len() {
        bail!("literal shape {:?} needs {} elems, got {}", shape, elems, data.len());
    }
    let lit = xla::Literal::vec1(data);
    if shape.len() <= 1 {
        Ok(lit)
    } else {
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        Ok(lit.reshape(&dims)?)
    }
}

impl TrainRuntime {
    /// Load + compile the artifacts. `with_fwd` also compiles the inference
    /// executable (used by evaluation / Fig 15).
    pub fn load(artifacts_dir: &Path, dense: DenseImpl, with_fwd: bool) -> Result<TrainRuntime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        let grads_exe = compile(&client, &manifest.artifact_path(dense.grads_key())?)?;
        let fwd_exe = if with_fwd {
            Some(compile(&client, &manifest.artifact_path("fwd")?)?)
        } else {
            None
        };
        Ok(TrainRuntime { manifest, client, grads_exe, fwd_exe })
    }

    /// Execute one training step.
    ///
    /// `x`: `[B,1,N,N]` flat, `y`: `[B,2,N,N]` flat, `mask`: `[B]` — where
    /// `B` is the manifest batch (callers pad + mask shorter batches).
    /// Returns the masked loss SUM and summed gradients.
    pub fn grads(&self, params: &ParamStore, x: &[f32], y: &[f32], mask: &[f32]) -> Result<StepOut> {
        let b = self.manifest.batch;
        let n = self.manifest.img;
        let mut args: Vec<xla::Literal> = Vec::with_capacity(self.manifest.params.len() + 3);
        for (spec, tensor) in self.manifest.params.iter().zip(params.tensors.iter()) {
            args.push(literal_from(tensor, &spec.shape)?);
        }
        args.push(literal_from(x, &[b, 1, n, n])?);
        args.push(literal_from(y, &[b, 2, n, n])?);
        args.push(literal_from(mask, &[b])?);

        let result = self.grads_exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let mut parts = result.to_tuple()?;
        if parts.len() != 1 + self.manifest.params.len() {
            bail!("grads returned {} outputs, expected {}", parts.len(), 1 + self.manifest.params.len());
        }
        let loss_sum = parts.remove(0).to_vec::<f32>()?[0];
        let mut grads = Vec::with_capacity(parts.len());
        for (spec, lit) in self.manifest.params.iter().zip(parts.into_iter()) {
            let v = lit.to_vec::<f32>()?;
            if v.len() != spec.elems() {
                bail!("grad '{}' has {} elems, expected {}", spec.name, v.len(), spec.elems());
            }
            grads.push(v);
        }
        Ok(StepOut { loss_sum, grads })
    }

    /// Inference: `x` `[B,1,N,N]` flat → `[B,2,N,N]` flat prediction.
    pub fn forward(&self, params: &ParamStore, x: &[f32]) -> Result<Vec<f32>> {
        let exe = self.fwd_exe.as_ref().context("runtime loaded without fwd executable")?;
        let b = self.manifest.batch;
        let n = self.manifest.img;
        let mut args: Vec<xla::Literal> = Vec::with_capacity(self.manifest.params.len() + 1);
        for (spec, tensor) in self.manifest.params.iter().zip(params.tensors.iter()) {
            args.push(literal_from(tensor, &spec.shape)?);
        }
        args.push(literal_from(x, &[b, 1, n, n])?);
        let result = exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple1()?.to_vec::<f32>()?)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> std::path::PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// Artifacts on disk AND a real PJRT runtime linked in (the offline
    /// xla stub can load manifests but not execute).
    fn have_artifacts() -> bool {
        if !artifacts_dir().join("manifest.json").exists() {
            return false;
        }
        if !crate::runtime::pjrt_available() {
            eprintln!("artifacts present but {}", crate::runtime::PJRT_UNAVAILABLE);
            return false;
        }
        true
    }

    /// Full AOT round-trip: python-lowered HLO → rust compile → execute.
    /// Skipped (with a note) when `make artifacts` hasn't run.
    #[test]
    fn grads_execute_and_mask_semantics() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let rt = TrainRuntime::load(&artifacts_dir(), DenseImpl::Xla, false).unwrap();
        let params = ParamStore::load_init(&rt.manifest).unwrap();
        let b = rt.manifest.batch;
        let n = rt.manifest.img;
        let x: Vec<f32> = (0..b * n * n).map(|i| ((i % 97) as f32) / 97.0).collect();
        let y: Vec<f32> = (0..b * 2 * n * n).map(|i| ((i % 31) as f32) / 31.0).collect();

        // Full mask vs half mask: the masked loss must shrink and the
        // half-masked loss must equal the loss of the first half only.
        let full = rt.grads(&params, &x, &y, &vec![1.0; b]).unwrap();
        let mut half_mask = vec![0.0f32; b];
        for m in half_mask.iter_mut().take(b / 2) {
            *m = 1.0;
        }
        let half = rt.grads(&params, &x, &y, &half_mask).unwrap();
        assert!(half.loss_sum < full.loss_sum);
        assert_eq!(full.grads.len(), rt.manifest.params.len());
        // Gradients should be non-trivial.
        let gnorm: f64 = full.grads.iter().flatten().map(|&g| (g as f64).powi(2)).sum::<f64>();
        assert!(gnorm > 0.0);
    }

    #[test]
    fn pallas_and_xla_artifacts_agree() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let rt_p = TrainRuntime::load(&artifacts_dir(), DenseImpl::Pallas, false).unwrap();
        let rt_x = TrainRuntime::load(&artifacts_dir(), DenseImpl::Xla, false).unwrap();
        let params = ParamStore::load_init(&rt_p.manifest).unwrap();
        let b = rt_p.manifest.batch;
        let n = rt_p.manifest.img;
        let x: Vec<f32> = (0..b * n * n).map(|i| ((i * 7 % 13) as f32) / 13.0).collect();
        let y: Vec<f32> = vec![0.25; b * 2 * n * n];
        let mask = vec![1.0f32; b];
        let a = rt_p.grads(&params, &x, &y, &mask).unwrap();
        let bb = rt_x.grads(&params, &x, &y, &mask).unwrap();
        let rel = ((a.loss_sum - bb.loss_sum) / bb.loss_sum).abs();
        assert!(rel < 1e-3, "pallas loss {} vs xla loss {}", a.loss_sum, bb.loss_sum);
    }

    #[test]
    fn sgd_on_real_runtime_reduces_loss() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let rt = TrainRuntime::load(&artifacts_dir(), DenseImpl::Xla, false).unwrap();
        let mut params = ParamStore::load_init(&rt.manifest).unwrap();
        let b = rt.manifest.batch;
        let n = rt.manifest.img;
        let x: Vec<f32> = (0..b * n * n).map(|i| ((i % 101) as f32) / 101.0).collect();
        let y: Vec<f32> = (0..b * 2 * n * n).map(|i| ((i % 53) as f32) / 53.0).collect();
        let mask = vec![1.0f32; b];
        let first = rt.grads(&params, &x, &y, &mask).unwrap();
        let mut loss_prev = first.loss_sum;
        for _ in 0..3 {
            let out = rt.grads(&params, &x, &y, &mask).unwrap();
            let mean: Vec<Vec<f32>> =
                out.grads.iter().map(|g| g.iter().map(|v| v / b as f32).collect()).collect();
            params.sgd_step(&mean, 0.05);
            loss_prev = out.loss_sum;
        }
        let last = rt.grads(&params, &x, &y, &mask).unwrap();
        assert!(
            last.loss_sum < first.loss_sum,
            "loss should decrease: {} -> {} (prev {})",
            first.loss_sum,
            last.loss_sum,
            loss_prev
        );
    }

    #[test]
    fn literal_shape_validation() {
        assert!(literal_from(&[1.0, 2.0], &[3]).is_err());
        let l = literal_from(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap().len(), 4);
    }
}
