//! The pluggable sample-storage API.
//!
//! SOLAR's Optim_3 is a storage-layer optimization (chunked reads against
//! a parallel file system), but nothing above the storage layer should
//! care *where* the bytes live. [`SampleStore`] is the seam: an
//! object-safe trait of positioned, `&self`-concurrent reads over a
//! fixed-size-record dataset, plus a [`Contiguity`] hint that tells the
//! chunk-aggregation cost path which sample ranges are byte-contiguous on
//! storage (so it never plans a "single request" that would actually span
//! two files).
//!
//! Three backends ship behind the trait:
//! * the single-file SHDF container ([`ShdfReader`], this module's impl);
//! * a sharded dataset — a directory of SHDF shards plus a manifest
//!   ([`super::shard::ShardedStore`]), the realistic layout when
//!   scientific data arrives as one file per simulation run;
//! * an in-memory store ([`MemStore`]) so driver and engine tests need no
//!   temp-file fixtures.
//!
//! All backends must be byte-for-byte interchangeable: `train()` produces
//! bit-identical `TrainReport`s whether the same samples live in one file
//! or N shards (see `tests/store_conformance.rs` and
//! `tests/driver_pipeline_parity.rs`).

use anyhow::{bail, Context, Result};
use std::path::Path;
use std::sync::Arc;

use crate::storage::codec::Codec;
use crate::storage::shdf::ShdfReader;

/// Abstract read-only store of fixed-size samples.
///
/// Contract (enforced by the shared conformance suite):
/// * reads are positioned and take `&self` — many threads may read through
///   one shared handle concurrently with no coordination;
/// * `read_sample_into_at(i, buf)` requires `buf.len() == sample_bytes()`
///   and errors (never panics) for `i >= n_samples()`;
/// * `read_range_into_at(start, count, buf)` requires
///   `buf.len() == count * sample_bytes()`, errors when
///   `start + count > n_samples()`, and a zero-length read
///   (`count == 0`, `start <= n_samples()`) is an Ok no-op;
/// * `chunk_contiguity()` describes which sample ranges are
///   byte-contiguous on the underlying storage (one region per file/shard)
///   — the scheduler only aggregates chunk reads within a region.
pub trait SampleStore: Send + Sync + std::fmt::Debug {
    /// Number of samples in the store.
    fn n_samples(&self) -> usize;

    /// Bytes per (fixed-size) sample.
    fn sample_bytes(&self) -> usize;

    /// Logical tensor shape of one sample (e.g. `[4, 64, 64]`).
    fn shape(&self) -> &[usize];

    /// Free-form dataset name.
    fn dataset_name(&self) -> &str;

    /// Positioned read of one sample into `buf` (`sample_bytes` long).
    fn read_sample_into_at(&self, i: usize, buf: &mut [u8]) -> Result<()>;

    /// Positioned read of `count` consecutive samples starting at `start`.
    /// Backends issue as few underlying requests as the layout allows (one
    /// for a range inside a contiguous region).
    fn read_range_into_at(&self, start: usize, count: usize, buf: &mut [u8]) -> Result<()>;

    /// Layout hint for the chunk-aggregation cost path.
    fn chunk_contiguity(&self) -> Contiguity;

    /// Positioned read of one sample, allocating.
    fn read_sample_at(&self, i: usize) -> Result<Vec<u8>> {
        let mut buf = vec![0u8; self.sample_bytes()];
        self.read_sample_into_at(i, &mut buf)?;
        Ok(buf)
    }

    /// Positioned range read, allocating.
    fn read_range_at(&self, start: usize, count: usize) -> Result<Vec<u8>> {
        let mut buf = vec![0u8; count * self.sample_bytes()];
        self.read_range_into_at(start, count, &mut buf)?;
        Ok(buf)
    }

    /// Positioned range read into a **reusable** buffer: `buf` is resized
    /// to the exact byte length and filled in place, so a buffer recycled
    /// across calls stops allocating once its capacity has grown to the
    /// largest range it carries — the parallel fetch pool's pooled-buffer
    /// path (`loader::io`).
    fn read_range_reusing_at(&self, start: usize, count: usize, buf: &mut Vec<u8>) -> Result<()> {
        // resize (no clear): a recycled buffer whose length already
        // matches is left untouched — the read overwrites every byte, so
        // zero-filling the whole range first would be a wasted memset on
        // exactly the steady-state path this method exists to serve.
        buf.resize(count * self.sample_bytes(), 0);
        self.read_range_into_at(start, count, buf)
    }

    /// The chunk codec this store's payload is written with. `Raw` for
    /// every legacy layout; when not raw, the decoded-byte read methods
    /// above still serve decoded samples (decompressing internally), and
    /// the fetch pool uses [`SampleStore::read_span_raw_at`] to pull the
    /// compressed extents and decompress on its own workers.
    fn codec(&self) -> Codec {
        Codec::Raw
    }

    /// Positioned read of the **raw on-storage bytes** backing samples
    /// `[start, start + count)` into a reusable buffer (resized to the
    /// span's exact byte length). On a raw store this is the decoded
    /// range; on a compressed store it is the concatenated encoded
    /// extents, which [`Codec::decode_f32_into`] walks by consumed bytes.
    /// The span must lie inside one contiguity region (chunk aggregation
    /// never bridges regions, so the fetch path guarantees this).
    fn read_span_raw_at(&self, start: usize, count: usize, buf: &mut Vec<u8>) -> Result<()> {
        self.read_range_reusing_at(start, count, buf)
    }
}

/// Decode a sample byte buffer as f32 (little-endian) — the one record
/// encoding every backend shares.
pub fn decode_f32(bytes: &[u8]) -> Vec<f32> {
    bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect()
}

/// Encode f32 samples as little-endian bytes — `decode_f32`'s inverse,
/// shared by every writer/backend so the record encoding lives in one
/// place.
pub fn encode_f32(sample: &[f32]) -> Vec<u8> {
    let mut bytes = Vec::with_capacity(sample.len() * 4);
    for &x in sample {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    bytes
}

/// Contiguity map of a store: its samples form a sequence of regions;
/// within a region, sample `i + 1` directly follows sample `i` on storage
/// (so a range read is ONE request), while across regions there is no
/// byte adjacency (a different shard file, or a header gap).
///
/// Offsets are *per-store virtual addresses*: absolute file offsets for a
/// single-file store, and offsets into the notional concatenation of the
/// shard files for a sharded store. Only deltas within a region are
/// physically meaningful — exactly what the PFS cost model charges — but
/// offsets stay monotone across regions so cross-region jumps still model
/// as long seeks.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Contiguity {
    /// `(first sample id of the region, virtual byte offset of that
    /// sample)`, ascending by sample id; the first region starts at 0.
    regions: Vec<(u32, u64)>,
    sample_bytes: u64,
    /// Variable per-sample extents (compressed layouts). When present,
    /// `offset_of`/`span_bytes` read these instead of the uniform-stride
    /// arithmetic; when absent every sample occupies `sample_bytes` on
    /// storage.
    var: Option<Arc<VarExtents>>,
}

/// Per-sample extent table of a variable-size (compressed) layout.
/// Offsets live in the same virtual address space as the region bases.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VarExtents {
    /// Virtual byte offset of each sample's extent (length `n_samples`).
    pub offsets: Vec<u64>,
    /// Virtual end of each region's payload (length `n_regions`) — what a
    /// span reaching a region's last sample ends at, so a chunk read
    /// never swallows the next shard's header gap.
    pub region_ends: Vec<u64>,
}

impl Contiguity {
    /// Single contiguous region (one flat file) with sample 0 at
    /// `data_start`.
    pub fn single(data_start: u64, sample_bytes: usize) -> Contiguity {
        Contiguity { regions: vec![(0, data_start)], sample_bytes: sample_bytes as u64, var: None }
    }

    /// Multi-region map. Regions must be ascending and start at sample 0;
    /// an empty list degenerates to one region at offset 0.
    pub fn from_regions(regions: Vec<(u32, u64)>, sample_bytes: usize) -> Contiguity {
        if regions.is_empty() {
            return Contiguity::single(0, sample_bytes);
        }
        assert_eq!(regions[0].0, 0, "first contiguity region must start at sample 0");
        for w in regions.windows(2) {
            assert!(w[0].0 < w[1].0, "contiguity regions must be strictly ascending");
        }
        Contiguity { regions, sample_bytes: sample_bytes as u64, var: None }
    }

    /// Attach a variable per-sample extent table (compressed layouts).
    /// Offsets must be monotone and consistent with the region list.
    pub fn with_var_extents(mut self, var: Arc<VarExtents>) -> Contiguity {
        assert_eq!(var.region_ends.len(), self.regions.len(), "one end per region");
        assert!(var.offsets.windows(2).all(|w| w[0] <= w[1]), "extent offsets must be monotone");
        for (k, &(start, base)) in self.regions.iter().enumerate() {
            if let Some(&o) = var.offsets.get(start as usize) {
                assert_eq!(o, base, "region {k} base must equal its first sample's extent offset");
            }
        }
        self.var = Some(var);
        self
    }

    /// Whether samples occupy variable-size extents (a compressed layout).
    pub fn is_var(&self) -> bool {
        self.var.is_some()
    }

    pub fn n_regions(&self) -> usize {
        self.regions.len()
    }

    pub fn is_single(&self) -> bool {
        self.regions.len() == 1
    }

    fn region_index(&self, x: u32) -> usize {
        // First region starts at 0, so partition_point ≥ 1.
        self.regions.partition_point(|&(start, _)| start <= x) - 1
    }

    /// Virtual byte offset of sample `x`.
    pub fn offset_of(&self, x: u32) -> u64 {
        if let Some(v) = &self.var {
            return v.offsets[x as usize];
        }
        let (start, base) = self.regions[self.region_index(x)];
        base + (x - start) as u64 * self.sample_bytes
    }

    /// On-storage byte length of the span covering samples
    /// `[lo, lo + count)`, which must lie inside one contiguity region.
    /// Uniform layouts answer `count * sample_bytes`; variable
    /// (compressed) layouts answer the exact extent span — the length a
    /// `ReadReq` carries, so the cost model charges the bytes that
    /// actually cross the PFS.
    pub fn span_bytes(&self, lo: u32, count: u32) -> u64 {
        if count == 0 {
            return 0;
        }
        let Some(v) = &self.var else {
            return count as u64 * self.sample_bytes;
        };
        let k = self.region_index(lo);
        let hi = lo + count;
        debug_assert!(
            hi - 1 < self.region_end(lo),
            "span [{lo}, {hi}) crosses a contiguity region boundary"
        );
        let end = match v.offsets.get(hi as usize) {
            Some(&o) if hi < self.region_end(lo) => o,
            _ => v.region_ends[k],
        };
        end - v.offsets[lo as usize]
    }

    /// First sample id past `x`'s contiguous region (`u32::MAX` for the
    /// last region).
    pub fn region_end(&self, x: u32) -> u32 {
        self.regions.get(self.region_index(x) + 1).map_or(u32::MAX, |&(start, _)| start)
    }

    /// Index of the region holding sample `x` (one region per
    /// file/shard) — the fetch pool's group-by-shard key.
    pub fn region_of(&self, x: u32) -> usize {
        self.region_index(x)
    }
}

// ---- backend: the single-file SHDF container ----

impl SampleStore for ShdfReader {
    fn n_samples(&self) -> usize {
        ShdfReader::n_samples(self)
    }

    fn sample_bytes(&self) -> usize {
        ShdfReader::sample_bytes(self)
    }

    fn shape(&self) -> &[usize] {
        &self.header().shape
    }

    fn dataset_name(&self) -> &str {
        &self.header().name
    }

    fn read_sample_into_at(&self, i: usize, buf: &mut [u8]) -> Result<()> {
        ShdfReader::read_sample_into_at(self, i, buf)
    }

    fn read_range_into_at(&self, start: usize, count: usize, buf: &mut [u8]) -> Result<()> {
        ShdfReader::read_range_into_at(self, start, count, buf)
    }

    fn chunk_contiguity(&self) -> Contiguity {
        let c = Contiguity::single(self.offset_of(0), ShdfReader::sample_bytes(self));
        match self.extent_index() {
            None => c,
            Some(idx) => {
                let n = ShdfReader::n_samples(self);
                c.with_var_extents(Arc::new(VarExtents {
                    offsets: idx[..n].to_vec(),
                    region_ends: vec![idx[n]],
                }))
            }
        }
    }

    fn codec(&self) -> Codec {
        ShdfReader::codec(self)
    }

    fn read_span_raw_at(&self, start: usize, count: usize, buf: &mut Vec<u8>) -> Result<()> {
        ShdfReader::read_span_raw_at(self, start, count, buf)
    }
}

// ---- backend: in-memory synthetic store ----

/// In-memory store: all samples in one `Vec<u8>`. For tests and tiny
/// synthetic runs — no filesystem, no fixtures, same read semantics.
#[derive(Clone)]
pub struct MemStore {
    name: String,
    shape: Vec<usize>,
    sample_bytes: usize,
    data: Vec<u8>,
}

// Manual Debug: the derive would dump every data byte, and a MemStore
// rides inside TrainConfig (Debug) — a printed config must not flood the
// log with megabytes of sample bytes.
impl std::fmt::Debug for MemStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MemStore")
            .field("name", &self.name)
            .field("shape", &self.shape)
            .field("sample_bytes", &self.sample_bytes)
            .field("data_len", &self.data.len())
            .finish()
    }
}

impl MemStore {
    /// Wrap raw sample bytes. `data.len()` must be a whole number of
    /// samples of the shape's f32 size.
    pub fn new(name: &str, shape: Vec<usize>, data: Vec<u8>) -> Result<MemStore> {
        let sample_bytes = shape.iter().product::<usize>() * 4;
        if shape.is_empty() || sample_bytes == 0 {
            bail!("sample shape {shape:?} has zero elements");
        }
        if data.len() % sample_bytes != 0 {
            bail!(
                "{} data bytes is not a whole number of {sample_bytes}-byte samples",
                data.len()
            );
        }
        Ok(MemStore { name: name.to_string(), shape, sample_bytes, data })
    }

    /// Append one f32 sample (builder-style convenience for tests).
    pub fn push_f32(&mut self, sample: &[f32]) -> Result<()> {
        if sample.len() * 4 != self.sample_bytes {
            bail!("sample is {} f32s, expected {}", sample.len(), self.sample_bytes / 4);
        }
        self.data.extend_from_slice(&encode_f32(sample));
        Ok(())
    }
}

impl SampleStore for MemStore {
    fn n_samples(&self) -> usize {
        self.data.len() / self.sample_bytes
    }

    fn sample_bytes(&self) -> usize {
        self.sample_bytes
    }

    fn shape(&self) -> &[usize] {
        &self.shape
    }

    fn dataset_name(&self) -> &str {
        &self.name
    }

    fn read_sample_into_at(&self, i: usize, buf: &mut [u8]) -> Result<()> {
        let n = SampleStore::n_samples(self);
        if i >= n {
            bail!("sample index {i} out of range ({n} samples)");
        }
        assert_eq!(buf.len(), self.sample_bytes);
        let lo = i * self.sample_bytes;
        buf.copy_from_slice(&self.data[lo..lo + self.sample_bytes]);
        Ok(())
    }

    fn read_range_into_at(&self, start: usize, count: usize, buf: &mut [u8]) -> Result<()> {
        if start + count > SampleStore::n_samples(self) {
            bail!("range [{start}, {}) out of range", start + count);
        }
        assert_eq!(buf.len(), count * self.sample_bytes);
        let lo = start * self.sample_bytes;
        buf.copy_from_slice(&self.data[lo..lo + count * self.sample_bytes]);
        Ok(())
    }

    fn chunk_contiguity(&self) -> Contiguity {
        Contiguity::single(0, self.sample_bytes)
    }
}

/// Open a dataset at `path` behind the trait: a directory is a sharded
/// dataset (manifest + shard files), anything else a single SHDF file.
pub fn open_store(path: &Path) -> Result<Arc<dyn SampleStore>> {
    if path.is_dir() {
        Ok(Arc::new(
            super::shard::ShardedStore::open(path)
                .with_context(|| format!("open sharded dataset {}", path.display()))?,
        ))
    } else {
        Ok(Arc::new(
            ShdfReader::open(path).with_context(|| format!("open dataset {}", path.display()))?,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mem(n: usize, elems: usize) -> MemStore {
        let mut m = MemStore::new("t", vec![elems], Vec::new()).unwrap();
        for i in 0..n {
            let s: Vec<f32> = (0..elems).map(|j| (i * 100 + j) as f32).collect();
            m.push_f32(&s).unwrap();
        }
        m
    }

    #[test]
    fn mem_store_reads_and_bounds() {
        let m = mem(6, 4);
        assert_eq!(SampleStore::n_samples(&m), 6);
        assert_eq!(SampleStore::sample_bytes(&m), 16);
        let s3 = decode_f32(&m.read_sample_at(3).unwrap());
        assert_eq!(s3, vec![300.0, 301.0, 302.0, 303.0]);
        let r = m.read_range_at(2, 3).unwrap();
        assert_eq!(decode_f32(&r[..16]), vec![200.0, 201.0, 202.0, 203.0]);
        assert!(SampleStore::read_sample_at(&m, 6).is_err());
        assert!(m.read_range_at(5, 2).is_err());
        // Zero-length reads are Ok up to (and at) the end.
        assert!(m.read_range_into_at(6, 0, &mut []).is_ok());
        assert!(m.read_range_into_at(7, 0, &mut []).is_err());
    }

    #[test]
    fn mem_store_rejects_ragged_data() {
        assert!(MemStore::new("t", vec![4], vec![0u8; 17]).is_err());
        assert!(MemStore::new("t", vec![], vec![]).is_err());
        let mut m = mem(1, 4);
        assert!(m.push_f32(&[1.0]).is_err());
    }

    #[test]
    fn reusing_range_read_recycles_capacity() {
        let m = mem(8, 4);
        let mut buf = Vec::new();
        m.read_range_reusing_at(2, 3, &mut buf).unwrap();
        assert_eq!(decode_f32(&buf[..16]), vec![200.0, 201.0, 202.0, 203.0]);
        let cap = buf.capacity();
        // A smaller follow-up read shrinks the length, never the capacity.
        m.read_range_reusing_at(5, 1, &mut buf).unwrap();
        assert_eq!(buf.len(), 16);
        assert_eq!(buf.capacity(), cap);
        assert_eq!(decode_f32(&buf), vec![500.0, 501.0, 502.0, 503.0]);
        assert!(m.read_range_reusing_at(7, 2, &mut buf).is_err());
    }

    #[test]
    fn contiguity_single_region() {
        let c = Contiguity::single(4108, 16);
        assert!(c.is_single());
        assert_eq!(c.offset_of(0), 4108);
        assert_eq!(c.offset_of(10), 4108 + 160);
        assert_eq!(c.region_end(5), u32::MAX);
        assert_eq!(c.region_of(5), 0);
    }

    #[test]
    fn contiguity_multi_region_offsets_and_ends() {
        // Two shards of 10 samples (16 B each), second file based at 5000.
        let c = Contiguity::from_regions(vec![(0, 100), (10, 5000)], 16);
        assert_eq!(c.n_regions(), 2);
        assert_eq!(c.offset_of(9), 100 + 9 * 16);
        assert_eq!(c.offset_of(10), 5000);
        assert_eq!(c.offset_of(14), 5000 + 4 * 16);
        assert_eq!(c.region_end(0), 10);
        assert_eq!(c.region_end(9), 10);
        assert_eq!(c.region_end(10), u32::MAX);
        assert_eq!(c.region_of(9), 0);
        assert_eq!(c.region_of(10), 1);
    }

    #[test]
    fn contiguity_empty_degenerates_to_single() {
        let c = Contiguity::from_regions(vec![], 8);
        assert!(c.is_single());
        assert_eq!(c.offset_of(3), 24);
    }

    #[test]
    #[should_panic]
    fn contiguity_rejects_nonzero_first_region() {
        let _ = Contiguity::from_regions(vec![(5, 0)], 8);
    }

    #[test]
    fn span_bytes_uniform_is_stride_arithmetic() {
        let c = Contiguity::from_regions(vec![(0, 100), (10, 5000)], 16);
        assert!(!c.is_var());
        assert_eq!(c.span_bytes(0, 0), 0);
        assert_eq!(c.span_bytes(3, 4), 64);
        assert_eq!(c.span_bytes(10, 5), 80);
    }

    #[test]
    fn span_bytes_var_uses_exact_extents() {
        // Two regions of 3 samples each; extents of 5/7/9 bytes then
        // 4/4/4, with a header gap before the second region's base (200).
        let var = Arc::new(VarExtents {
            offsets: vec![100, 105, 112, 200, 204, 208],
            region_ends: vec![121, 212],
        });
        let c = Contiguity::from_regions(vec![(0, 100), (3, 200)], 16).with_var_extents(var);
        assert!(c.is_var());
        assert_eq!(c.offset_of(1), 105);
        assert_eq!(c.offset_of(3), 200);
        assert_eq!(c.span_bytes(0, 1), 5);
        assert_eq!(c.span_bytes(0, 2), 12);
        // A span reaching a region's LAST sample ends at the region's
        // payload end, not at the next region's base — the header gap
        // between 121 and 200 is never charged.
        assert_eq!(c.span_bytes(0, 3), 21);
        assert_eq!(c.span_bytes(2, 1), 9);
        assert_eq!(c.span_bytes(3, 3), 12);
        assert_eq!(c.span_bytes(5, 1), 4);
        assert_eq!(c.span_bytes(4, 0), 0);
    }

    #[test]
    #[should_panic]
    fn var_extents_reject_region_base_mismatch() {
        let var = Arc::new(VarExtents { offsets: vec![100, 105], region_ends: vec![110] });
        let _ = Contiguity::single(99, 8).with_var_extents(var);
    }
}
